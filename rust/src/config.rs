//! Simulation configuration — Table 2 of the paper, plus DaeMon §4.5
//! structure sizes and the experiment knobs (bandwidth factor, switch
//! latency, partitioning ratio, replacement policy, topology).
//!
//! All times are kept in **core cycles** internally (3.6 GHz ⇒ 1 ns = 3.6
//! cycles); helpers convert from ns.

use crate::compress::Algo;
use crate::system::fault::{FaultPlan, RecoveryPolicy};

/// Core clock in GHz (Table 2: 3.6 GHz x86 OoO).
pub const CORE_GHZ: f64 = 3.6;

/// Convert nanoseconds to core cycles.
#[inline]
pub fn ns_to_cycles(ns: f64) -> f64 {
    ns * CORE_GHZ
}

/// Cache line and page geometry.
pub const LINE_BYTES: u64 = 64;
pub const PAGE_BYTES: u64 = 4096;
pub const LINES_PER_PAGE: u64 = PAGE_BYTES / LINE_BYTES;

/// Local-memory replacement policy (§6, Fig. 16).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Replacement {
    Lru,
    Fifo,
}

/// Which compression-size oracle the link compression units use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompressEstimator {
    /// Native rust implementations of the real algorithms (ground truth).
    Exact,
    /// The AOT-compiled L1/L2 model executed through PJRT, batched.
    Pjrt,
}

/// Per-level cache parameters.
#[derive(Clone, Copy, Debug)]
pub struct CacheParams {
    pub size_bytes: u64,
    pub ways: usize,
    pub latency_cycles: f64,
    pub mshrs: usize,
}

/// One network hop between a compute component and a memory component.
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// Propagation + switching delay, ns (paper: 100–400 ns, up to 1 µs).
    pub switch_latency_ns: f64,
    /// Network bandwidth = DRAM bus bandwidth / bandwidth_factor
    /// (paper: factor 2–16).
    pub bandwidth_factor: f64,
}

impl NetConfig {
    pub fn new(switch_latency_ns: f64, bandwidth_factor: f64) -> Self {
        Self { switch_latency_ns, bandwidth_factor }
    }

    /// Link bandwidth in bytes per core cycle.
    pub fn bytes_per_cycle(&self, dram_gbps: f64) -> f64 {
        (dram_gbps / self.bandwidth_factor) / CORE_GHZ
    }
}

/// DaeMon hardware structure sizes (§4.5, Table 1).
#[derive(Clone, Copy, Debug)]
pub struct DaemonParams {
    pub subblock_queue: usize,       // 128 (compute) — LLC MSHR bound
    pub page_queue: usize,           // 256
    pub inflight_subblock_buf: usize, // 128
    pub inflight_page_buf: usize,    // 256
    pub dirty_data_buf: usize,       // 256
    /// Dirty-line flush threshold per page (§4.3, "e.g., 8 cache lines").
    pub dirty_flush_threshold: usize,
    /// Bandwidth partitioning ratio reserved for cache lines (§4.1, 25%).
    pub partition_ratio: f64,
    /// Compression algorithm for link compression (§4.4: LZ-MXT).
    pub compress: Option<Algo>,
    /// (De)compression latency in cycles per page.  MXT: 64 cycles per 1KB
    /// chunk, 4 chunks pipelined across 4 engines ⇒ ~64 + pipeline fill;
    /// we charge 64 cycles/KB serialized per direction = 256.
    pub compress_cycles: f64,
}

impl Default for DaemonParams {
    fn default() -> Self {
        Self {
            subblock_queue: 128,
            page_queue: 256,
            inflight_subblock_buf: 128,
            inflight_page_buf: 256,
            dirty_data_buf: 256,
            dirty_flush_threshold: 8,
            partition_ratio: 0.25,
            compress: Some(Algo::Lz),
            compress_cycles: 256.0,
        }
    }
}

/// How idle capacity on a shared memory-module resource is treated.
///
/// `Strict` is §4.1's reservation discipline lifted to tenants: a share
/// is reserved even while its owner idles, so "contention" shows up only
/// as a smaller share and per-tenant slowdown stays well-defined (QoS
/// isolation).  `WorkConserving` redistributes capacity that is idle *at
/// request time* — a peer tenant's unused port/bus queue, or the sibling
/// class channel inside a partitioned share — proportionally to the
/// candidates' service rates (deficit-style: borrowed bytes are charged
/// to the lending channel's timeline, so a lender that wakes up queues
/// behind what it lent).  Strict mode takes the exact pre-existing code
/// path and is byte-identical to the historical results.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SharingMode {
    #[default]
    Strict,
    WorkConserving,
}

impl SharingMode {
    /// Registry id of this mode's [`crate::policy::SharingPolicy`].
    pub fn name(&self) -> &'static str {
        crate::policy::sharing(*self).id()
    }
}

/// Plain-data description of a square-wave link-condition schedule (the
/// §6 "high runtime variability in network latencies/bandwidth" regime):
/// alternating degraded / nominal phases of `period_cycles` each,
/// starting degraded at cycle 0, until `horizon_cycles` (nominal after).
/// `net::disturbance::NetSchedule::from_spec` materializes it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScheduleSpec {
    pub period_cycles: f64,
    /// Bandwidth multiplier during degraded phases, in (0, 1].
    pub rate_scale: f64,
    /// Extra switch latency during degraded phases, ns.
    pub extra_latency_ns: f64,
    pub horizon_cycles: f64,
}

/// Closed-loop controller configuration (ROADMAP item 3 / DaeMon §4.5
/// taken online): which control laws run and at what observation epoch.
/// The controller is a pure function of sampled state — see
/// [`crate::system::controller::AdaptiveController`] and the registry in
/// [`crate::policy::adaptive`].  `epoch_cycles == 0.0` (or all laws off)
/// makes the controller fully inert: no observation, no actuation, and
/// the run stays byte-identical to the same config without a controller.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ControllerSpec {
    /// Observation/actuation cadence in sim cycles (0.0 = inert).
    pub epoch_cycles: f64,
    /// Enable the `ratio-tune` law (migration-ratio retuning).
    pub tune_ratio: bool,
    /// Enable the `recovery-switch` law (Stall↔Refetch switching).
    pub switch_recovery: bool,
    /// Enable the `share-rebalance` law (idle-share reclamation under
    /// work-conserving sharing; inert under strict sharing).
    pub rebalance_shares: bool,
}

impl ControllerSpec {
    /// All three control laws at the given epoch.
    pub fn all(epoch_cycles: f64) -> ControllerSpec {
        ControllerSpec {
            epoch_cycles,
            tune_ratio: true,
            switch_recovery: true,
            rebalance_shares: true,
        }
    }

    /// True when this spec can never observe or actuate.
    pub fn is_inert(&self) -> bool {
        self.epoch_cycles <= 0.0
            || !(self.tune_ratio || self.switch_recovery || self.rebalance_shares)
    }
}

/// Arrival-rate shape for the request-serving front-end
/// ([`crate::system::frontend`]): a piecewise-constant rate multiplier
/// over a base Poisson process, mirroring the `NetSchedule` phase
/// machinery on the workload side.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalPattern {
    /// Constant nominal rate for the whole run.
    Steady,
    /// Square-wave high/low phases (burst first), mean rate ≈ nominal.
    Bursty,
    /// A staircase approximating a day/night cycle: ramp up to a peak
    /// and back down, repeating.
    Diurnal,
}

impl ArrivalPattern {
    pub fn name(self) -> &'static str {
        match self {
            ArrivalPattern::Steady => "steady",
            ArrivalPattern::Bursty => "bursty",
            ArrivalPattern::Diurnal => "diurnal",
        }
    }
}

/// Plain-data description of one request-serving scenario (ROADMAP
/// item 2): open-loop arrivals fanned into access bursts, served under
/// an SLO with an optional robustness stack.  Carried as
/// `Option<ServiceSpec>` on a cluster cell — `None` keeps the exact
/// historical trace-driven path, byte for byte.  The robustness knobs
/// are layered: `timeout_cycles <= 0` disables timeouts *and* retries
/// (the "naive" stack), `hedge_percentile <= 0` disables hedging,
/// `shed_watermark_cycles <= 0` disables admission control.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServiceSpec {
    pub pattern: ArrivalPattern,
    /// Number of requests in the run.
    pub requests: usize,
    /// Accesses per request burst (window of the class's base trace).
    pub burst_accesses: usize,
    /// Mean inter-arrival gap in cycles at `load == 1.0`.
    pub base_gap_cycles: f64,
    /// Arrival-rate multiplier: the effective mean gap is
    /// `base_gap_cycles / load`, so `load > 1` overdrives the servers.
    pub load: f64,
    /// SLO deadline measured from arrival; completions within it count
    /// toward goodput-under-SLO.
    pub slo_cycles: f64,
    /// Per-attempt timeout measured from issue (<= 0.0 = naive: no
    /// timeouts, no retries).
    pub timeout_cycles: f64,
    /// Retry budget after the first attempt times out.
    pub max_retries: u32,
    /// First retry backoff; doubles per retry up to the cap.
    pub backoff_base_cycles: f64,
    pub backoff_cap_cycles: f64,
    /// Deterministic jitter added on top of each backoff, as a fraction
    /// of the capped deterministic delay (in `[0, jitter_frac)`).
    pub jitter_frac: f64,
    /// Hedge a second attempt once the primary is outstanding past this
    /// percentile of observed attempt latencies (<= 0.0 = off).
    pub hedge_percentile: f64,
    /// Shed at admission when even the least-loaded server's busy
    /// backlog exceeds this many cycles (<= 0.0 = off).
    pub shed_watermark_cycles: f64,
    /// Seed for the service-layer splitmix64 stream (arrivals, class
    /// mix, burst windows, jitter) — independent of the sim PRNG.
    pub seed: u64,
}

impl ServiceSpec {
    /// The naive stack: serve every request, wait forever.
    pub fn naive(
        pattern: ArrivalPattern,
        requests: usize,
        burst_accesses: usize,
        base_gap_cycles: f64,
        load: f64,
        slo_cycles: f64,
    ) -> ServiceSpec {
        ServiceSpec {
            pattern,
            requests,
            burst_accesses,
            base_gap_cycles,
            load,
            slo_cycles,
            timeout_cycles: 0.0,
            max_retries: 0,
            backoff_base_cycles: 0.0,
            backoff_cap_cycles: 0.0,
            jitter_frac: 0.0,
            hedge_percentile: 0.0,
            shed_watermark_cycles: 0.0,
            seed: 0xDAE_5,
        }
    }

    /// Layer on deadlines + bounded exponential-backoff retries.
    pub fn with_retry(
        mut self,
        timeout_cycles: f64,
        max_retries: u32,
        backoff_base_cycles: f64,
        backoff_cap_cycles: f64,
        jitter_frac: f64,
    ) -> ServiceSpec {
        self.timeout_cycles = timeout_cycles;
        self.max_retries = max_retries;
        self.backoff_base_cycles = backoff_base_cycles;
        self.backoff_cap_cycles = backoff_cap_cycles;
        self.jitter_frac = jitter_frac;
        self
    }

    /// Layer on hedged second issues at the given latency percentile.
    pub fn with_hedge(mut self, percentile: f64) -> ServiceSpec {
        self.hedge_percentile = percentile;
        self
    }

    /// Layer on admission-control load shedding at the given watermark.
    pub fn with_shed(mut self, watermark_cycles: f64) -> ServiceSpec {
        self.shed_watermark_cycles = watermark_cycles;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> ServiceSpec {
        self.seed = seed;
        self
    }

    /// Deadline + retry machinery active?
    pub fn has_timeouts(&self) -> bool {
        self.timeout_cycles > 0.0
    }

    pub fn has_hedge(&self) -> bool {
        self.hedge_percentile > 0.0
    }

    pub fn has_shed(&self) -> bool {
        self.shed_watermark_cycles > 0.0
    }
}

/// One tenant's share of every shared memory-module resource (fabric port
/// + DRAM bus): a bandwidth weight, plus that tenant's own §4.1 class
/// partitioning applied *within* its share.  Shares are strict (reserved
/// even while other tenants idle), mirroring how the paper's queue
/// controllers reserve per-class bandwidth — this is what gives the
/// cluster its QoS isolation.
#[derive(Clone, Copy, Debug)]
pub struct TenantShare {
    /// Relative bandwidth weight (normalized over all tenants).
    pub weight: f64,
    /// Class-partition this tenant's share into line/page sub-channels.
    pub partitioned: bool,
    /// Fraction of the share reserved for cache lines when partitioned.
    pub line_ratio: f64,
}

impl TenantShare {
    /// Normalized per-tenant service rates for a shared resource of
    /// `total` bytes/cycle — the single splitting rule both the fabric
    /// ports and the memory-engine bus queues use, so the two can never
    /// diverge.  Rejects empty share lists and non-positive weights.
    pub fn rates(shares: &[TenantShare], total: f64) -> Vec<f64> {
        assert!(!shares.is_empty(), "at least one tenant share required");
        for s in shares {
            assert!(
                s.weight.is_finite() && s.weight > 0.0,
                "tenant weights must be positive and finite, got {}",
                s.weight
            );
        }
        let wsum: f64 = shares.iter().map(|s| s.weight).sum();
        shares.iter().map(|s| total * (s.weight / wsum)).collect()
    }
}

/// Multi-tenant cluster topology (§6.7 scenario): C tenants — independent
/// compute components, each with its own trace/profile/scheme — sharing M
/// memory modules through a switched fabric.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub memory_modules: usize,
    /// Per-port link parameters (switch latency + bandwidth factor).
    pub net: NetConfig,
    /// Extra per-traversal fabric hop latency, ns.  At 0 the fabric is
    /// timing-identical to the point-to-point links, so a single-tenant
    /// cluster reproduces `Machine` exactly (regression-tested).
    pub fabric_hop_ns: f64,
    /// Per-tenant bandwidth weights (empty = equal shares).
    pub weights: Vec<f64>,
    /// How idle tenant/class capacity is treated on the fabric ports and
    /// DRAM bus queues (default: strict shares, the historical behavior).
    pub sharing: SharingMode,
    /// Time-varying link conditions applied to every fabric port
    /// (`None` = steady nominal conditions).
    pub schedule: Option<ScheduleSpec>,
    /// Fault-injection plan (module crashes, link flaps, tenant kills)
    /// materialized onto the shared fabric and memory engines; `None` =
    /// no faults.  Requires [`SharingMode::Strict`].
    pub faults: Option<FaultPlan>,
    /// Degraded-mode policy tenants use while a home module is down.
    pub recovery: RecoveryPolicy,
    /// Closed-loop controller (`None` or an inert spec = today's static
    /// behavior, byte-identical).
    pub controller: Option<ControllerSpec>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            memory_modules: 1,
            net: NetConfig::new(100.0, 4.0),
            fabric_hop_ns: 0.0,
            weights: Vec::new(),
            sharing: SharingMode::Strict,
            schedule: None,
            faults: None,
            recovery: RecoveryPolicy::Stall,
            controller: None,
        }
    }
}

impl ClusterConfig {
    pub fn new(memory_modules: usize) -> Self {
        Self { memory_modules: memory_modules.max(1), ..Self::default() }
    }

    pub fn with_net(mut self, switch_ns: f64, bw_factor: f64) -> Self {
        self.net = NetConfig::new(switch_ns, bw_factor);
        self
    }

    pub fn with_hop(mut self, hop_ns: f64) -> Self {
        self.fabric_hop_ns = hop_ns;
        self
    }

    pub fn with_weights(mut self, weights: Vec<f64>) -> Self {
        self.weights = weights;
        self
    }

    pub fn with_sharing(mut self, sharing: SharingMode) -> Self {
        self.sharing = sharing;
        self
    }

    pub fn with_schedule(mut self, schedule: ScheduleSpec) -> Self {
        self.schedule = Some(schedule);
        self
    }

    pub fn with_faults(mut self, faults: FaultPlan) -> Self {
        self.faults = Some(faults);
        self
    }

    pub fn with_recovery(mut self, recovery: RecoveryPolicy) -> Self {
        self.recovery = recovery;
        self
    }

    pub fn with_controller(mut self, controller: ControllerSpec) -> Self {
        self.controller = Some(controller);
        self
    }

    /// The per-module link configurations the fabric is built from.
    pub fn nets(&self) -> Vec<NetConfig> {
        vec![self.net; self.memory_modules.max(1)]
    }

    /// Check cross-field invariants that individual setters cannot see.
    /// Today that is one rule, sourced from the policy registry: a fault
    /// plan requires a sharing policy with
    /// [`supports_faults`](crate::policy::SharingPolicy::supports_faults)
    /// — the work-conserving borrow planner would lend a down port's
    /// capacity away, silently erasing the fault.  `Cluster::new` calls
    /// this and panics with the message; callers assembling configs
    /// programmatically can call it early for a descriptive error
    /// instead.
    pub fn validate(&self) -> Result<(), String> {
        if self.faults.is_some() && !crate::policy::sharing(self.sharing).supports_faults() {
            return Err(format!(
                "fault injection requires SharingMode::Strict (the work-conserving \
                 borrow planner would lend a down port's capacity away), but \
                 ClusterConfig.sharing is {:?}",
                self.sharing
            ));
        }
        Ok(())
    }
}

/// Full system configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    // Cache hierarchy (Table 2).
    pub l1d: CacheParams,
    pub l2: CacheParams,
    pub llc: CacheParams,
    // Core.
    pub rob_entries: usize,
    pub issue_width: usize,
    /// Base CPI of non-memory instructions (4-wide ⇒ 0.25).
    pub base_cpi: f64,
    // Memory (Table 2: DDR4-2400, 17 GB/s, 15 ns processing).
    pub dram_gbps: f64,
    pub dram_latency_ns: f64,
    /// Local page-table / tag metadata lookup on a local-memory access.
    pub local_meta_ns: f64,
    /// Hardware address translation at the memory component = one DRAM
    /// access per lookup (Clio-style, §5).
    pub remote_translate_ns: f64,
    // Local memory sizing: fraction of the working set (paper: ~20%).
    pub local_mem_fraction: f64,
    pub replacement: Replacement,
    // Network to each memory component.
    pub net: Vec<NetConfig>,
    /// Page placement across memory components.
    pub placement_round_robin: bool,
    // DaeMon engine parameters.
    pub daemon: DaemonParams,
    pub estimator: CompressEstimator,
    /// Cores per compute component (1 for Fig. 8, 8 for Fig. 15/21,
    /// 4 for Fig. 18).
    pub cores: usize,
    /// Memory-level parallelism window per core: outstanding long-latency
    /// misses the OoO core overlaps (bounded by ROB occupancy / LLC
    /// MSHRs; Sniper-style interval modeling).
    pub core_mlp: usize,
    /// Concurrency window for page-fault-style blocking remote accesses
    /// (Remote/LC): the kernel fault path serializes handling far more
    /// than the hardware MSHR path (LegoOS-style remote paging).
    pub fault_mlp: usize,
    /// Software overhead per page fault, ns (kernel entry/exit, page-table
    /// update, TLB shootdown — LegoOS-class remote paging; DaeMon's
    /// hardware engines eliminate this, which is part of the paper's
    /// baseline-vs-mechanism contrast).
    pub fault_overhead_ns: f64,
    /// Interval for bandwidth-utilization accounting, ns (paper: 100K ns).
    pub interval_ns: f64,
    /// Seed for all stochastic inputs (trace + content generation).
    pub seed: u64,
    /// §4.7 extension — next-page prefetcher: on a demand page migration,
    /// also schedule this many sequential successor pages (0 = off,
    /// the paper's default).  Prefetched pages go through the normal
    /// selection-granularity path, so DaeMon can throttle them.
    pub prefetch_pages: usize,
    /// §4.6 failure handling — dirty-data replication factor: evicted
    /// dirty data is written to this many memory components (1 = off).
    /// Replicas consume writeback bandwidth on distinct components.
    pub dirty_replicas: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            l1d: CacheParams { size_bytes: 32 << 10, ways: 8, latency_cycles: 4.0, mshrs: 16 },
            l2: CacheParams { size_bytes: 256 << 10, ways: 8, latency_cycles: 8.0, mshrs: 32 },
            llc: CacheParams { size_bytes: 4 << 20, ways: 16, latency_cycles: 30.0, mshrs: 128 },
            rob_entries: 224,
            issue_width: 4,
            base_cpi: 0.75,
            dram_gbps: 17.0,
            dram_latency_ns: 15.0,
            local_meta_ns: 15.0,
            remote_translate_ns: 15.0,
            local_mem_fraction: 0.20,
            replacement: Replacement::Lru,
            net: vec![NetConfig::new(100.0, 4.0)],
            placement_round_robin: true,
            daemon: DaemonParams::default(),
            estimator: CompressEstimator::Exact,
            cores: 1,
            core_mlp: 16,
            fault_mlp: 4,
            fault_overhead_ns: 500.0,
            interval_ns: 100_000.0,
            seed: 0xDAE_0,
            prefetch_pages: 0,
            dirty_replicas: 1,
        }
    }
}

impl SimConfig {
    /// The paper's default single-component operating point.
    pub fn paper_default() -> Self {
        Self::default()
    }

    /// Proportionally shrunken cache hierarchy for `Scale::Test` traces
    /// (whose working sets are ~0.5–10 MB): keeps footprint ≫ LLC, the
    /// regime the paper evaluates, while unit tests stay fast.
    pub fn test_scale() -> Self {
        let mut c = Self::default();
        c.l1d.size_bytes = 8 << 10;
        c.l2.size_bytes = 32 << 10;
        c.llc = CacheParams { size_bytes: 256 << 10, ways: 16, latency_cycles: 30.0, mshrs: 128 };
        c
    }

    pub fn with_net(mut self, switch_ns: f64, bw_factor: f64) -> Self {
        self.net = vec![NetConfig::new(switch_ns, bw_factor)];
        self
    }

    pub fn with_memory_components(mut self, nets: Vec<NetConfig>) -> Self {
        self.net = nets;
        self
    }

    pub fn with_replacement(mut self, r: Replacement) -> Self {
        self.replacement = r;
        self
    }

    pub fn with_cores(mut self, cores: usize) -> Self {
        self.cores = cores;
        self
    }

    pub fn with_partition_ratio(mut self, ratio: f64) -> Self {
        self.daemon.partition_ratio = ratio;
        self
    }

    pub fn with_compress(mut self, algo: Option<Algo>) -> Self {
        self.daemon.compress = algo;
        self
    }

    pub fn with_local_fraction(mut self, f: f64) -> Self {
        self.local_mem_fraction = f;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// §4.7: enable the next-page prefetcher.
    pub fn with_prefetch(mut self, pages: usize) -> Self {
        self.prefetch_pages = pages;
        self
    }

    /// §4.6: replicate dirty data to `n` memory components.
    pub fn with_dirty_replicas(mut self, n: usize) -> Self {
        self.dirty_replicas = n.max(1);
        self
    }

    /// DRAM bus bandwidth in bytes per core cycle.
    pub fn dram_bytes_per_cycle(&self) -> f64 {
        self.dram_gbps / CORE_GHZ
    }

    /// Cache-line service rate ratio implied by the bandwidth partitioning
    /// (§4.1): lines per page slot, e.g. 25% ⇒ ~21.
    pub fn lines_per_page_slot(&self) -> f64 {
        let r = self.daemon.partition_ratio;
        (PAGE_BYTES as f64 / LINE_BYTES as f64) * r / (1.0 - r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_partition_ratio_gives_21_lines_per_page() {
        let c = SimConfig::default();
        let lpp = c.lines_per_page_slot();
        assert!((lpp - 21.333).abs() < 0.01, "{lpp}");
    }

    #[test]
    fn ns_conversion() {
        assert!((ns_to_cycles(100.0) - 360.0).abs() < 1e-9);
    }

    #[test]
    fn net_bandwidth_quarter_factor() {
        let n = NetConfig::new(100.0, 4.0);
        // 17/4 GB/s at 3.6GHz = ~1.18 B/cycle.
        let bpc = n.bytes_per_cycle(17.0);
        assert!((bpc - 17.0 / 4.0 / 3.6).abs() < 1e-9, "{bpc}");
    }

    #[test]
    fn builders_compose() {
        let c = SimConfig::default()
            .with_net(400.0, 8.0)
            .with_cores(8)
            .with_partition_ratio(0.5)
            .with_replacement(Replacement::Fifo);
        assert_eq!(c.net[0].switch_latency_ns, 400.0);
        assert_eq!(c.cores, 8);
        assert_eq!(c.replacement, Replacement::Fifo);
        assert!((c.lines_per_page_slot() - 64.0).abs() < 1e-9);
    }

    #[test]
    fn tenant_share_rates_split_by_weight() {
        let sh = |w| TenantShare { weight: w, partitioned: false, line_ratio: 0.25 };
        let r = TenantShare::rates(&[sh(3.0), sh(1.0)], 8.0);
        assert_eq!(r, vec![6.0, 2.0]);
        assert_eq!(TenantShare::rates(&[sh(1.0)], 4.2), vec![4.2]);
    }

    #[test]
    #[should_panic(expected = "positive and finite")]
    fn tenant_share_rejects_nonpositive_weight() {
        let sh = |w| TenantShare { weight: w, partitioned: false, line_ratio: 0.25 };
        let _ = TenantShare::rates(&[sh(2.0), sh(0.0)], 8.0);
    }

    #[test]
    fn cluster_config_builders() {
        let spec = ScheduleSpec {
            period_cycles: 1e6,
            rate_scale: 0.5,
            extra_latency_ns: 100.0,
            horizon_cycles: 1e9,
        };
        let plan = FaultPlan::new().module_crash(0, 1.0, 2.0);
        let c = ClusterConfig::new(4)
            .with_net(400.0, 8.0)
            .with_hop(50.0)
            .with_weights(vec![2.0, 1.0])
            .with_sharing(SharingMode::WorkConserving)
            .with_schedule(spec);
        assert_eq!(c.memory_modules, 4);
        assert_eq!(c.nets().len(), 4);
        assert_eq!(c.net.switch_latency_ns, 400.0);
        assert_eq!(c.fabric_hop_ns, 50.0);
        assert_eq!(c.weights, vec![2.0, 1.0]);
        assert_eq!(c.sharing, SharingMode::WorkConserving);
        assert_eq!(c.schedule, Some(spec));
        let f = ClusterConfig::new(2)
            .with_faults(plan.clone())
            .with_recovery(RecoveryPolicy::Refetch);
        assert_eq!(f.faults, Some(plan));
        assert_eq!(f.recovery, RecoveryPolicy::Refetch);
        assert_eq!(ClusterConfig::new(0).memory_modules, 1);
        // Strict, steady, fault-free conditions remain the default.
        let d = ClusterConfig::default();
        assert_eq!(d.sharing, SharingMode::Strict);
        assert_eq!(d.schedule, None);
        assert_eq!(d.faults, None);
        assert_eq!(d.recovery, RecoveryPolicy::Stall);
        assert_eq!(SharingMode::WorkConserving.name(), "work-conserving");
    }

    #[test]
    fn controller_spec_inertness_and_builder() {
        let c = ClusterConfig::default();
        assert_eq!(c.controller, None, "no controller by default");
        let spec = ControllerSpec::all(25_000.0);
        assert!(!spec.is_inert());
        assert!(ControllerSpec::all(0.0).is_inert(), "epoch 0 is inert");
        let laws_off = ControllerSpec {
            epoch_cycles: 25_000.0,
            tune_ratio: false,
            switch_recovery: false,
            rebalance_shares: false,
        };
        assert!(laws_off.is_inert(), "all laws off is inert");
        let c = ClusterConfig::new(2).with_controller(spec);
        assert_eq!(c.controller, Some(spec));
    }

    #[test]
    fn cluster_config_validate_gates_faults_by_sharing_capability() {
        let plan = FaultPlan::new().module_crash(0, 1.0, 2.0);
        // Fault-free configs validate under either sharing mode.
        assert_eq!(ClusterConfig::new(2).validate(), Ok(()));
        let wc = ClusterConfig::new(2).with_sharing(SharingMode::WorkConserving);
        assert_eq!(wc.validate(), Ok(()));
        // Faults + strict sharing is the supported combination.
        let ok = ClusterConfig::new(2).with_faults(plan.clone());
        assert_eq!(ok.validate(), Ok(()));
        // Faults + work-conserving is rejected with a descriptive error.
        let bad = wc.with_faults(plan);
        let err = bad.validate().unwrap_err();
        assert!(err.contains("requires SharingMode::Strict"), "got: {err}");
        assert!(err.contains("WorkConserving"), "got: {err}");
    }

    #[test]
    fn default_matches_table2() {
        let c = SimConfig::default();
        assert_eq!(c.l1d.size_bytes, 32 * 1024);
        assert_eq!(c.llc.size_bytes, 4 * 1024 * 1024);
        assert_eq!(c.llc.ways, 16);
        assert_eq!(c.rob_entries, 224);
        assert_eq!(c.dram_gbps, 17.0);
    }
}
