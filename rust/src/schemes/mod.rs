//! Data-movement schemes (§2.2 motivation set + §6 evaluation set).
//!
//! Every scheme is a policy over the same machine: which granularities
//! move, whether the link/remote bus are partitioned (§4.1), whether the
//! selection-granularity unit throttles requests (§4.2), and whether pages
//! are link-compressed (§4.4).

/// The nine schemes evaluated across Figs. 3 and 8–22.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SchemeKind {
    /// Monolithic upper bound: all data fits in local memory.
    Local,
    /// Cache-line-granularity remote access only (no local memory use).
    CacheLine,
    /// The widely-adopted baseline: page-granularity migration.
    Remote,
    /// Idealized: line-latency access + free page migration (Fig. 3).
    PageFree,
    /// Naive both-granularities on a shared FIFO link (Fig. 3).
    CacheLinePage,
    /// Link compression on page movement only (§6 "LC").
    Lc,
    /// Decoupled dual-granularity with bandwidth partitioning only ("BP").
    Bp,
    /// BP + selection granularity unit ("PQ").
    Pq,
    /// Full DaeMon: PQ + link compression.
    Daemon,
}

impl SchemeKind {
    /// All nine variants, in `policy::REGISTRY` (historical `by_name`)
    /// order.
    pub const ALL: [SchemeKind; 9] = [
        SchemeKind::Local,
        SchemeKind::CacheLine,
        SchemeKind::Remote,
        SchemeKind::PageFree,
        SchemeKind::CacheLinePage,
        SchemeKind::Lc,
        SchemeKind::Bp,
        SchemeKind::Pq,
        SchemeKind::Daemon,
    ];

    /// Display name — delegates to the registered `MovementPolicy`.
    pub fn name(&self) -> &'static str {
        crate::policy::movement_for(*self).display()
    }

    /// Canonical `--scheme` id — delegates to the registered policy.
    pub fn id(&self) -> &'static str {
        crate::policy::movement_for(*self).id()
    }

    /// Resolve by canonical id or alias (case-insensitive).  The
    /// `policy::REGISTRY` is the single source of ids and aliases.
    pub fn by_name(name: &str) -> Option<SchemeKind> {
        crate::policy::movement(name).map(|p| p.kind())
    }

    /// Policy flags the machine driver consumes — delegates to the
    /// registered `MovementPolicy`.
    pub fn policy(&self) -> Policy {
        crate::policy::movement_for(*self).flags()
    }

    /// The §6 evaluation set (Fig. 8) in plot order.
    pub fn eval_set() -> [SchemeKind; 5] {
        [SchemeKind::Lc, SchemeKind::Bp, SchemeKind::Pq, SchemeKind::Daemon, SchemeKind::Local]
    }

    /// The §2.2 motivation set (Fig. 3) in plot order.
    pub fn motivation_set() -> [SchemeKind; 6] {
        [
            SchemeKind::Local,
            SchemeKind::CacheLine,
            SchemeKind::Remote,
            SchemeKind::PageFree,
            SchemeKind::CacheLinePage,
            SchemeKind::Daemon,
        ]
    }
}

/// Decomposed policy flags.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Policy {
    /// All accesses hit local memory (monolithic).
    pub local_only: bool,
    /// Page migrations to local memory are performed.
    pub move_pages: bool,
    /// Cache-line movements straight to LLC are performed.
    pub move_lines: bool,
    /// The requesting access stalls until the page arrives (page-fault
    /// semantics); otherwise the access can be served by a line.
    pub blocking_pages: bool,
    /// Pages arrive instantly and free (the Fig. 3 idealization).
    pub free_pages: bool,
    /// §4.1 bandwidth partitioning (separate line/page channels).
    pub partitioned: bool,
    /// §4.2 selection granularity unit (inflight-buffer driven).
    pub selection: bool,
    /// §4.4 link compression on page movement.
    pub compress: bool,
    /// Lines are installed via page in local memory (false only for the
    /// pure cache-line scheme, which bypasses local memory).
    pub install_pages: bool,
}

impl Policy {
    /// The all-off baseline the registry entries build on (`const` so
    /// `policy::REGISTRY` statics can use struct-update syntax).
    pub(crate) const fn none() -> Policy {
        Policy {
            local_only: false,
            move_pages: false,
            move_lines: false,
            blocking_pages: false,
            free_pages: false,
            partitioned: false,
            selection: false,
            compress: false,
            install_pages: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip_exhaustively() {
        // `ALL` covers every variant exactly once (the match below fails
        // to compile if a tenth variant appears without being listed).
        assert_eq!(SchemeKind::ALL.len(), 9);
        for (i, k) in SchemeKind::ALL.iter().enumerate() {
            assert!(!SchemeKind::ALL[..i].contains(k), "{k:?} listed twice");
            let _covered = match k {
                SchemeKind::Local
                | SchemeKind::CacheLine
                | SchemeKind::Remote
                | SchemeKind::PageFree
                | SchemeKind::CacheLinePage
                | SchemeKind::Lc
                | SchemeKind::Bp
                | SchemeKind::Pq
                | SchemeKind::Daemon => (),
            };
            // Display name, canonical id and case-folding all round-trip.
            assert_eq!(SchemeKind::by_name(k.name()), Some(*k), "{k:?}");
            assert_eq!(SchemeKind::by_name(k.id()), Some(*k), "{k:?}");
            assert_eq!(
                SchemeKind::by_name(&k.name().to_ascii_uppercase()),
                Some(*k),
                "{k:?}"
            );
        }
        assert_eq!(SchemeKind::by_name("nope"), None);
    }

    #[test]
    fn historical_aliases_resolve() {
        for (alias, k) in [
            ("cacheline", SchemeKind::CacheLine),
            ("cl", SchemeKind::CacheLine),
            ("pagefree", SchemeKind::PageFree),
            ("clp", SchemeKind::CacheLinePage),
            ("naive", SchemeKind::CacheLinePage),
        ] {
            assert_eq!(SchemeKind::by_name(alias), Some(k), "{alias}");
        }
    }

    #[test]
    fn daemon_enables_all_three_techniques() {
        let p = SchemeKind::Daemon.policy();
        assert!(p.partitioned && p.selection && p.compress);
        assert!(p.move_pages && p.move_lines);
        assert!(!p.blocking_pages);
    }

    #[test]
    fn remote_is_blocking_page_only() {
        let p = SchemeKind::Remote.policy();
        assert!(p.move_pages && p.blocking_pages);
        assert!(!p.move_lines && !p.compress && !p.partitioned);
    }

    #[test]
    fn pq_is_daemon_without_compression() {
        let pq = SchemeKind::Pq.policy();
        let dm = SchemeKind::Daemon.policy();
        assert_eq!(Policy { compress: true, ..pq }, dm);
    }

    #[test]
    fn cache_line_bypasses_local_memory() {
        let p = SchemeKind::CacheLine.policy();
        assert!(p.move_lines && !p.move_pages && !p.install_pages);
    }

    #[test]
    fn eval_and_motivation_sets_match_paper() {
        assert_eq!(SchemeKind::eval_set().len(), 5);
        assert_eq!(SchemeKind::motivation_set().len(), 6);
        assert_eq!(SchemeKind::motivation_set()[0], SchemeKind::Local);
    }
}
