//! PJRT runtime: load the AOT-compiled L2/L1 compression cost model
//! (`artifacts/compress_model.hlo.txt`, produced once by
//! `python/compile/aot.py`) and execute it from the rust hot path.
//!
//! Python never runs at simulation time: the HLO text is parsed and
//! compiled by the `xla` crate's PJRT CPU client at startup, then executed
//! as a native function.  The model batches `AOT_BATCH` pages per call —
//! the [`PjrtOracle`] fills batches with neighbouring page ids so one
//! dispatch covers a whole miss neighbourhood.
//!
//! **Feature gating:** the PJRT backend needs the `xla` and `anyhow`
//! crates plus a local XLA toolchain, none of which exist in the offline
//! build environment.  The whole backend sits behind the off-by-default
//! `pjrt` cargo feature; without it [`ModelRunner::load`] /
//! [`ModelRunner::load_default`] return a clear error (so callers and
//! tests skip gracefully) and the simulator uses the native exact oracle —
//! the default either way.  The public API is identical under both builds.

use crate::compress::synth::{gen_page_words, Profile};
use crate::system::SizeOracle;
use crate::util::hash::FxHashMap;
use crate::util::prng::Rng;

/// Must match `python/compile/model.py::AOT_BATCH`.
pub const AOT_BATCH: usize = 64;
/// Words per 4KB page (i32 view) — matches the L1 kernel.
pub const WORDS_PER_PAGE: usize = 1024;

/// Default artifact location relative to the repo root.
pub const DEFAULT_ARTIFACT: &str = "artifacts/compress_model.hlo.txt";

/// Network operating point handed to the cost model (params vector —
/// see model.py for the layout).
#[derive(Clone, Copy, Debug)]
pub struct NetParams {
    pub link_bytes_per_cycle: f32,
    pub switch_cycles: f32,
    pub partition_ratio: f32,
    pub line_bytes: f32,
    pub decomp_cycles: f32,
    pub mem_bytes_per_cycle: f32,
}

impl NetParams {
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    fn to_vec(self) -> Vec<f32> {
        vec![
            self.link_bytes_per_cycle,
            self.switch_cycles,
            self.partition_ratio,
            self.line_bytes,
            self.decomp_cycles,
            self.mem_bytes_per_cycle,
        ]
    }

    /// The paper's default operating point (1/4 bandwidth, 100ns switch,
    /// 25% partitioning).
    pub fn paper_default() -> Self {
        Self {
            link_bytes_per_cycle: (17.0 / 4.0 / 3.6) as f32,
            switch_cycles: 360.0,
            partition_ratio: 0.25,
            line_bytes: 64.0,
            decomp_cycles: 256.0,
            mem_bytes_per_cycle: (17.0 / 3.6) as f32,
        }
    }
}

/// One batch of model outputs.
#[derive(Clone, Debug)]
pub struct CostBatch {
    /// `[batch][algo]` estimated compressed bytes, algo = [lz, fpcbdi, fve].
    pub est_bytes: Vec<[f32; 3]>,
    pub page_cycles: Vec<f32>,
    pub line_cycles: Vec<f32>,
    /// log(page/line) cost — >0 means the line arrives first.
    pub advantage: Vec<f32>,
}

/// Compiled cost model on the PJRT CPU client (`pjrt` feature builds).
#[cfg(feature = "pjrt")]
mod backend {
    use super::{CostBatch, NetParams, AOT_BATCH, DEFAULT_ARTIFACT, WORDS_PER_PAGE};
    use anyhow::{Context, Result};
    use std::path::Path;

    pub struct ModelRunner {
        exe: xla::PjRtLoadedExecutable,
    }

    impl ModelRunner {
        /// Load + compile the HLO artifact.  Fails with a helpful message
        /// if `make artifacts` has not produced it.
        pub fn load(path: &Path) -> Result<ModelRunner> {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| {
                format!(
                    "load HLO artifact {path:?} — run `make artifacts` to build it"
                )
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).context("PJRT compile")?;
            Ok(ModelRunner { exe })
        }

        /// Locate the artifact relative to the crate root or cwd.
        pub fn load_default() -> Result<ModelRunner> {
            let candidates = [
                Path::new(DEFAULT_ARTIFACT).to_path_buf(),
                Path::new(env!("CARGO_MANIFEST_DIR")).join(DEFAULT_ARTIFACT),
            ];
            for c in &candidates {
                if c.exists() {
                    return Self::load(c);
                }
            }
            anyhow::bail!(
                "artifact {DEFAULT_ARTIFACT} not found — run `make artifacts`"
            )
        }

        /// Execute the model on one batch of exactly `AOT_BATCH` pages.
        pub fn run_batch(&self, pages: &[i32], params: NetParams) -> Result<CostBatch> {
            anyhow::ensure!(
                pages.len() == AOT_BATCH * WORDS_PER_PAGE,
                "expected {} words, got {}",
                AOT_BATCH * WORDS_PER_PAGE,
                pages.len()
            );
            let pages_lit = xla::Literal::vec1(pages)
                .reshape(&[AOT_BATCH as i64, WORDS_PER_PAGE as i64])?;
            let params_lit = xla::Literal::vec1(&params.to_vec()[..]);
            let result = self.exe.execute::<xla::Literal>(&[pages_lit, params_lit])?[0][0]
                .to_literal_sync()?;
            let (est, page_c, line_c, adv) = result.to_tuple4()?;
            let est_flat: Vec<f32> = est.to_vec()?;
            let est_bytes = est_flat
                .chunks_exact(3)
                .map(|c| [c[0], c[1], c[2]])
                .collect();
            Ok(CostBatch {
                est_bytes,
                page_cycles: page_c.to_vec()?,
                line_cycles: line_c.to_vec()?,
                advantage: adv.to_vec()?,
            })
        }
    }
}

/// Stub for offline builds: the loaders report that the backend is absent,
/// so `runner_or_skip`-style callers degrade to the exact oracle.
#[cfg(not(feature = "pjrt"))]
mod backend {
    use super::{CostBatch, NetParams};
    use std::path::Path;

    pub struct ModelRunner {
        _unconstructable: (),
    }

    impl ModelRunner {
        pub fn load(path: &Path) -> Result<ModelRunner, String> {
            Err(format!(
                "cannot load {path:?}: daemon-sim was built without the `pjrt` \
                 feature (the xla/anyhow crates are unavailable offline) — the \
                 native exact estimator is the supported default"
            ))
        }

        pub fn load_default() -> Result<ModelRunner, String> {
            Self::load(Path::new(super::DEFAULT_ARTIFACT))
        }

        pub fn run_batch(
            &self,
            _pages: &[i32],
            _params: NetParams,
        ) -> Result<CostBatch, String> {
            unreachable!("stub ModelRunner cannot be constructed")
        }
    }
}

pub use backend::ModelRunner;

/// [`SizeOracle`] backed by the PJRT cost model: compressed sizes come
/// from the AOT-compiled estimator instead of the native algorithms.
/// Misses are batched with neighbouring page ids so one PJRT dispatch
/// covers `AOT_BATCH` pages.
pub struct PjrtOracle {
    runner: ModelRunner,
    params: NetParams,
    seed: u64,
    profiles: Vec<Profile>,
    cache: FxHashMap<(usize, u64), u32>,
    raw_bytes: u64,
    compressed_bytes: u64,
    pub batches_run: u64,
}

impl PjrtOracle {
    pub fn new(runner: ModelRunner, params: NetParams, seed: u64, profiles: Vec<Profile>) -> Self {
        Self {
            runner,
            params,
            seed,
            profiles,
            cache: FxHashMap::default(),
            raw_bytes: 0,
            compressed_bytes: 0,
            batches_run: 0,
        }
    }

    fn page_words(&self, core: usize, page: u64) -> Vec<i32> {
        // Must match ExactOracle's per-core seeding + Compressor contents.
        let core = core.min(self.profiles.len() - 1);
        let seed = self.seed ^ (core as u64) << 32;
        let mut rng = Rng::new(seed ^ page.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        gen_page_words(&mut rng, self.profiles[core])
    }

    fn fill_batch(&mut self, core: usize, page: u64) {
        // The demanded page plus its neighbours (spatially adjacent pages
        // are the likeliest next migrations).
        let ids: Vec<u64> = (0..AOT_BATCH as u64).map(|i| page + i).collect();
        let mut words = Vec::with_capacity(AOT_BATCH * WORDS_PER_PAGE);
        for &id in &ids {
            words.extend_from_slice(&self.page_words(core, id));
        }
        let batch = self
            .runner
            .run_batch(&words, self.params)
            .expect("PJRT batch execution failed");
        self.batches_run += 1;
        for (i, &id) in ids.iter().enumerate() {
            // MXT transfers compressed data in 256B sectors (minimum one
            // sector); the hardware falls back to raw pages when
            // compression does not pay.
            let est = (batch.est_bytes[i][0].clamp(1.0, 4096.0) / 256.0).ceil() * 256.0;
            let est = est as u32;
            self.cache.insert((core, id), est);
        }
    }
}

impl SizeOracle for PjrtOracle {
    fn page_size(&mut self, core: usize, page: u64) -> u32 {
        let core = core.min(self.profiles.len() - 1);
        if let Some(&sz) = self.cache.get(&(core, page)) {
            self.raw_bytes += 4096;
            self.compressed_bytes += sz as u64;
            return sz;
        }
        self.fill_batch(core, page);
        let sz = self.cache[&(core, page)];
        self.raw_bytes += 4096;
        self.compressed_bytes += sz as u64;
        sz
    }

    fn ratio(&self) -> f64 {
        if self.compressed_bytes == 0 {
            1.0
        } else {
            self.raw_bytes as f64 / self.compressed_bytes as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_loaders_fail_with_feature_hint() {
        let err = ModelRunner::load_default().err().expect("stub must not load");
        assert!(err.contains("pjrt"), "unhelpful error: {err}");
        let err = ModelRunner::load(std::path::Path::new("x.hlo")).err().unwrap();
        assert!(err.contains("x.hlo"));
    }

    #[test]
    fn net_params_default_matches_paper_operating_point() {
        let p = NetParams::paper_default();
        assert_eq!(p.line_bytes, 64.0);
        assert_eq!(p.partition_ratio, 0.25);
        assert_eq!(p.switch_cycles, 360.0);
    }
}
