//! Simulation metrics: everything the paper's figures plot.

use crate::util::stats::Running;

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Total committed instructions (memory + gap).
    pub instructions: u64,
    /// Final core time in cycles (max over cores).
    pub cycles: f64,
    /// Raw latency (issue -> data arrival) of LLC-miss accesses.
    pub access_cost: Running,
    /// Memory stall cycles the core actually suffered (MLP-window blocking
    /// + final drain).  `mean_access_cost` = stalls per LLC miss — the
    /// quantity the paper's "data access cost" figure tracks (a scheme
    /// that overlaps transfers with execution has low cost even if
    /// individual transfers queue).
    pub stall_cycles: f64,
    /// Local memory hits/misses (LLC-miss accesses only).
    pub local_hits: u64,
    pub local_misses: u64,
    /// Pages migrated to local memory.
    pub pages_moved: u64,
    /// Page migrations suppressed by the selection unit / buffer limits.
    pub pages_throttled: u64,
    /// Cache-line movements to LLC.
    pub lines_moved: u64,
    /// Dirty traffic written back to remote (lines + pages), bytes.
    pub writeback_bytes: u64,
    /// Bytes moved over the network, compute-bound direction.
    pub net_bytes_in: u64,
    /// Mean network utilization over the run, [0,1].
    pub net_utilization: f64,
    /// Compression ratio achieved on migrated pages (1.0 if off).
    pub compression_ratio: f64,
    /// Per-interval instruction counts (Fig. 13 time series).
    pub interval_instructions: Vec<u64>,
    /// Per-interval local-memory hit counts / totals (Fig. 14).
    pub interval_local_hits: Vec<u64>,
    pub interval_local_total: Vec<u64>,
}

impl Metrics {
    pub fn new() -> Self {
        Self { compression_ratio: 1.0, access_cost: Running::new(), ..Default::default() }
    }

    pub fn ipc(&self) -> f64 {
        if self.cycles <= 0.0 {
            0.0
        } else {
            self.instructions as f64 / self.cycles
        }
    }

    pub fn local_hit_ratio(&self) -> f64 {
        let total = self.local_hits + self.local_misses;
        if total == 0 {
            // Schemes that never consult local memory (pure cache-line).
            0.0
        } else {
            self.local_hits as f64 / total as f64
        }
    }

    /// Stall-based data access cost: memory stall cycles per LLC-miss
    /// access (see `stall_cycles`).
    pub fn mean_access_cost(&self) -> f64 {
        if self.access_cost.n == 0 {
            0.0
        } else {
            self.stall_cycles / self.access_cost.n as f64
        }
    }

    /// Raw mean latency from issue to data arrival.
    pub fn mean_access_latency(&self) -> f64 {
        self.access_cost.mean()
    }

    /// Record an instruction count into the interval series.
    pub fn bump_interval(&mut self, interval: usize, instrs: u64) {
        if self.interval_instructions.len() <= interval {
            self.interval_instructions.resize(interval + 1, 0);
            self.interval_local_hits.resize(interval + 1, 0);
            self.interval_local_total.resize(interval + 1, 0);
        }
        self.interval_instructions[interval] += instrs;
    }

    pub fn bump_interval_local(&mut self, interval: usize, hit: bool) {
        if self.interval_local_total.len() <= interval {
            self.interval_instructions.resize(interval + 1, 0);
            self.interval_local_hits.resize(interval + 1, 0);
            self.interval_local_total.resize(interval + 1, 0);
        }
        self.interval_local_total[interval] += 1;
        if hit {
            self.interval_local_hits[interval] += 1;
        }
    }

    /// Per-interval IPC series (interval length in cycles supplied).
    pub fn ipc_series(&self, interval_cycles: f64) -> Vec<f64> {
        self.interval_instructions
            .iter()
            .map(|&i| i as f64 / interval_cycles)
            .collect()
    }

    /// Per-interval local hit-ratio series.
    pub fn hit_ratio_series(&self) -> Vec<f64> {
        self.interval_local_total
            .iter()
            .zip(&self.interval_local_hits)
            .map(|(&t, &h)| if t == 0 { 0.0 } else { h as f64 / t as f64 })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ipc_and_hit_ratio() {
        let mut m = Metrics::new();
        m.instructions = 1000;
        m.cycles = 2000.0;
        assert!((m.ipc() - 0.5).abs() < 1e-12);
        m.local_hits = 9;
        m.local_misses = 1;
        assert!((m.local_hit_ratio() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = Metrics::new();
        assert_eq!(m.ipc(), 0.0);
        assert_eq!(m.local_hit_ratio(), 0.0);
        assert_eq!(m.mean_access_cost(), 0.0);
        assert_eq!(m.compression_ratio, 1.0);
    }

    #[test]
    fn interval_series() {
        let mut m = Metrics::new();
        m.bump_interval(0, 100);
        m.bump_interval(2, 300);
        m.bump_interval_local(2, true);
        m.bump_interval_local(2, false);
        assert_eq!(m.ipc_series(100.0), vec![1.0, 0.0, 3.0]);
        assert_eq!(m.hit_ratio_series()[2], 0.5);
    }
}
