//! Simulation metrics: everything the paper's figures plot.

use crate::util::json::Json;
use crate::util::stats::{LogHistogram, Running};

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Total committed instructions (memory + gap).
    pub instructions: u64,
    /// Final core time in cycles (max over cores).
    pub cycles: f64,
    /// Raw latency (issue -> data arrival) of LLC-miss accesses.
    pub access_cost: Running,
    /// Log-bucketed distribution of the same latencies — tail quantiles
    /// (per-tenant p99) for the cluster fairness reports.
    pub access_hist: LogHistogram,
    /// Memory stall cycles the core actually suffered (MLP-window blocking
    /// + final drain).  `mean_access_cost` = stalls per LLC miss — the
    /// quantity the paper's "data access cost" figure tracks (a scheme
    /// that overlaps transfers with execution has low cost even if
    /// individual transfers queue).
    pub stall_cycles: f64,
    /// Local memory hits/misses (LLC-miss accesses only).
    pub local_hits: u64,
    pub local_misses: u64,
    /// Pages migrated to local memory.
    pub pages_moved: u64,
    /// Page migrations suppressed by the selection unit / buffer limits.
    pub pages_throttled: u64,
    /// Cache-line movements to LLC.
    pub lines_moved: u64,
    /// Dirty traffic written back to remote (lines + pages), bytes.
    pub writeback_bytes: u64,
    /// Bytes moved over the network, compute-bound direction.
    pub net_bytes_in: u64,
    /// Bytes served on borrowed (idle peer / sibling-class) capacity
    /// under work-conserving sharing — 0 in strict mode by construction.
    pub reclaimed_bytes: u64,
    /// Down time of this tenant's worst fabric port within the run
    /// horizon (max over modules — a single-module outage reports its
    /// full length), cycles; 0 when no fault plan is installed.
    pub downtime_cycles: f64,
    /// Transfers/DRAM accesses lost to a mid-flight component crash and
    /// replayed after recovery (fabric ports + memory engines).
    pub aborted_transfers: u64,
    /// Requests issued while their target component was down, deferred
    /// to the recovery edge (stall-until-recovery).
    pub deferred_requests: u64,
    /// Closed-loop controller actions applied to this tenant (ratio
    /// retunes, recovery switches, share rebalances) — 0 for static and
    /// no-op-controller runs by construction.
    pub controller_actuations: u64,
    /// Mean network utilization over the run, [0,1].
    pub net_utilization: f64,
    /// Per-interval downlink utilization, horizon-clipped (variability
    /// time series; averaged over this tenant's module ports).
    pub net_util_series: Vec<f64>,
    /// Compression ratio achieved on migrated pages (1.0 if off).
    pub compression_ratio: f64,
    /// Per-interval instruction counts (Fig. 13 time series).
    pub interval_instructions: Vec<u64>,
    /// Per-interval local-memory hit counts / totals (Fig. 14).
    pub interval_local_hits: Vec<u64>,
    pub interval_local_total: Vec<u64>,
    /// Request-serving ledger (service cells only; all zero elsewhere).
    /// The front-end books these on tenant 0 — see
    /// `system::frontend`.
    pub requests_completed: u64,
    /// Requests whose retry budget exhausted past their deadline.
    pub requests_timed_out: u64,
    /// Requests refused by admission control at the backlog watermark.
    pub requests_shed: u64,
    /// Retry attempts issued (re-issues after a deadline, not firsts).
    pub request_retries: u64,
    /// Hedged second attempts issued.
    pub request_hedges: u64,
    /// Completions where the hedged attempt reported first.
    pub request_hedge_wins: u64,
    /// Completions within the request SLO (`ServiceSpec::slo_cycles`).
    pub requests_slo_good: u64,
    /// End-to-end latency (arrival -> completion) of completed requests.
    pub request_hist: LogHistogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self { compression_ratio: 1.0, access_cost: Running::new(), ..Default::default() }
    }

    pub fn ipc(&self) -> f64 {
        if self.cycles <= 0.0 {
            0.0
        } else {
            self.instructions as f64 / self.cycles
        }
    }

    pub fn local_hit_ratio(&self) -> f64 {
        let total = self.local_hits + self.local_misses;
        if total == 0 {
            // Schemes that never consult local memory (pure cache-line).
            0.0
        } else {
            self.local_hits as f64 / total as f64
        }
    }

    /// Stall-based data access cost: memory stall cycles per LLC-miss
    /// access (see `stall_cycles`).
    pub fn mean_access_cost(&self) -> f64 {
        if self.access_cost.n == 0 {
            0.0
        } else {
            self.stall_cycles / self.access_cost.n as f64
        }
    }

    /// Raw mean latency from issue to data arrival.
    pub fn mean_access_latency(&self) -> f64 {
        self.access_cost.mean()
    }

    /// Network goodput toward the compute component over the run,
    /// bytes/cycle — the per-tenant quantity the work-conserving fabric
    /// must not decrease in aggregate.
    pub fn goodput(&self) -> f64 {
        if self.cycles <= 0.0 {
            0.0
        } else {
            self.net_bytes_in as f64 / self.cycles
        }
    }

    /// Approximate p99 of raw access latency (issue -> data arrival),
    /// cycles — the per-tenant tail metric the fairness reports use.
    pub fn p99_access_cost(&self) -> f64 {
        self.access_hist.value_at(0.99)
    }

    /// Requests offered to the front-end: every arrival reaches exactly
    /// one terminal state (completed, timed out, or shed).
    pub fn requests_offered(&self) -> u64 {
        self.requests_completed + self.requests_timed_out + self.requests_shed
    }

    /// Goodput under SLO: fraction of *offered* requests that completed
    /// within the deadline — timeouts and shed requests count against
    /// it, so partial service is rewarded only when it actually lands
    /// useful completions.
    pub fn slo_goodput(&self) -> f64 {
        let offered = self.requests_offered();
        if offered == 0 {
            0.0
        } else {
            self.requests_slo_good as f64 / offered as f64
        }
    }

    /// Approximate p99 of end-to-end request latency, cycles.
    pub fn p99_request(&self) -> f64 {
        self.request_hist.value_at(0.99)
    }

    /// Approximate p999 of end-to-end request latency, cycles.
    pub fn p999_request(&self) -> f64 {
        self.request_hist.value_at(0.999)
    }

    /// Record an instruction count into the interval series.
    pub fn bump_interval(&mut self, interval: usize, instrs: u64) {
        if self.interval_instructions.len() <= interval {
            self.interval_instructions.resize(interval + 1, 0);
            self.interval_local_hits.resize(interval + 1, 0);
            self.interval_local_total.resize(interval + 1, 0);
        }
        self.interval_instructions[interval] += instrs;
    }

    pub fn bump_interval_local(&mut self, interval: usize, hit: bool) {
        if self.interval_local_total.len() <= interval {
            self.interval_instructions.resize(interval + 1, 0);
            self.interval_local_hits.resize(interval + 1, 0);
            self.interval_local_total.resize(interval + 1, 0);
        }
        self.interval_local_total[interval] += 1;
        if hit {
            self.interval_local_hits[interval] += 1;
        }
    }

    /// Per-interval IPC series (interval length in cycles supplied).
    pub fn ipc_series(&self, interval_cycles: f64) -> Vec<f64> {
        self.interval_instructions
            .iter()
            .map(|&i| i as f64 / interval_cycles)
            .collect()
    }

    /// Per-interval local hit-ratio series.
    pub fn hit_ratio_series(&self) -> Vec<f64> {
        self.interval_local_total
            .iter()
            .zip(&self.interval_local_hits)
            .map(|(&t, &h)| if t == 0 { 0.0 } else { h as f64 / t as f64 })
            .collect()
    }

    /// Serialize every field for the sharded-sweep wire format.  f64s
    /// survive exactly (shortest-roundtrip printing); counters are well
    /// below 2^53 so the f64 carrier is lossless.
    pub fn to_json(&self) -> Json {
        let u64s =
            |v: &[u64]| Json::Arr(v.iter().map(|&x| Json::num(x as f64)).collect());
        let f64s = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::num(x)).collect());
        Json::obj(vec![
            ("instructions", Json::num(self.instructions as f64)),
            ("cycles", Json::num(self.cycles)),
            ("stall_cycles", Json::num(self.stall_cycles)),
            ("access_cost_n", Json::num(self.access_cost.n as f64)),
            ("access_cost_sum", Json::num(self.access_cost.sum)),
            ("access_cost_min", finite_or_null(self.access_cost.min)),
            ("access_cost_max", finite_or_null(self.access_cost.max)),
            ("local_hits", Json::num(self.local_hits as f64)),
            ("local_misses", Json::num(self.local_misses as f64)),
            ("pages_moved", Json::num(self.pages_moved as f64)),
            ("pages_throttled", Json::num(self.pages_throttled as f64)),
            ("lines_moved", Json::num(self.lines_moved as f64)),
            ("writeback_bytes", Json::num(self.writeback_bytes as f64)),
            ("net_bytes_in", Json::num(self.net_bytes_in as f64)),
            ("reclaimed_bytes", Json::num(self.reclaimed_bytes as f64)),
            ("downtime_cycles", Json::num(self.downtime_cycles)),
            ("aborted_transfers", Json::num(self.aborted_transfers as f64)),
            ("deferred_requests", Json::num(self.deferred_requests as f64)),
            ("controller_actuations", Json::num(self.controller_actuations as f64)),
            ("net_utilization", Json::num(self.net_utilization)),
            ("net_util_series", f64s(&self.net_util_series)),
            ("compression_ratio", Json::num(self.compression_ratio)),
            ("access_hist", u64s(&self.access_hist.counts)),
            ("interval_instructions", u64s(&self.interval_instructions)),
            ("interval_local_hits", u64s(&self.interval_local_hits)),
            ("interval_local_total", u64s(&self.interval_local_total)),
            ("requests_completed", Json::num(self.requests_completed as f64)),
            ("requests_timed_out", Json::num(self.requests_timed_out as f64)),
            ("requests_shed", Json::num(self.requests_shed as f64)),
            ("request_retries", Json::num(self.request_retries as f64)),
            ("request_hedges", Json::num(self.request_hedges as f64)),
            ("request_hedge_wins", Json::num(self.request_hedge_wins as f64)),
            ("requests_slo_good", Json::num(self.requests_slo_good as f64)),
            ("request_hist", u64s(&self.request_hist.counts)),
        ])
    }

    /// Inverse of [`Metrics::to_json`].
    pub fn from_json(j: &Json) -> Result<Metrics, String> {
        let mut m = Metrics::new();
        m.instructions = jint(j, "instructions")?;
        m.cycles = jnum(j, "cycles")?;
        m.stall_cycles = jnum(j, "stall_cycles")?;
        m.access_cost = Running {
            n: jint(j, "access_cost_n")?,
            sum: jnum(j, "access_cost_sum")?,
            min: jedge(j, "access_cost_min", f64::INFINITY),
            max: jedge(j, "access_cost_max", f64::NEG_INFINITY),
        };
        m.local_hits = jint(j, "local_hits")?;
        m.local_misses = jint(j, "local_misses")?;
        m.pages_moved = jint(j, "pages_moved")?;
        m.pages_throttled = jint(j, "pages_throttled")?;
        m.lines_moved = jint(j, "lines_moved")?;
        m.writeback_bytes = jint(j, "writeback_bytes")?;
        m.net_bytes_in = jint(j, "net_bytes_in")?;
        m.reclaimed_bytes = jint(j, "reclaimed_bytes")?;
        m.downtime_cycles = jnum(j, "downtime_cycles")?;
        m.aborted_transfers = jint(j, "aborted_transfers")?;
        m.deferred_requests = jint(j, "deferred_requests")?;
        m.controller_actuations = jint(j, "controller_actuations")?;
        m.net_utilization = jnum(j, "net_utilization")?;
        m.net_util_series = jvec_f64(j, "net_util_series")?;
        m.compression_ratio = jnum(j, "compression_ratio")?;
        let hist = jvec(j, "access_hist")?;
        if hist.len() != 64 {
            return Err(format!(
                "metrics json: 'access_hist' carries {} buckets, want 64",
                hist.len()
            ));
        }
        m.access_hist = LogHistogram::from_counts(&hist);
        m.interval_instructions = jvec(j, "interval_instructions")?;
        m.interval_local_hits = jvec(j, "interval_local_hits")?;
        m.interval_local_total = jvec(j, "interval_local_total")?;
        m.requests_completed = jint(j, "requests_completed")?;
        m.requests_timed_out = jint(j, "requests_timed_out")?;
        m.requests_shed = jint(j, "requests_shed")?;
        m.request_retries = jint(j, "request_retries")?;
        m.request_hedges = jint(j, "request_hedges")?;
        m.request_hedge_wins = jint(j, "request_hedge_wins")?;
        m.requests_slo_good = jint(j, "requests_slo_good")?;
        let rhist = jvec(j, "request_hist")?;
        if rhist.len() != 64 {
            return Err(format!(
                "metrics json: 'request_hist' carries {} buckets, want 64",
                rhist.len()
            ));
        }
        m.request_hist = LogHistogram::from_counts(&rhist);
        Ok(m)
    }
}

/// Per-tenant slowdown of a shared (cluster) run versus the same tenant
/// running alone on the same topology: solo IPC / shared IPC.
pub fn slowdown(solo: &Metrics, shared: &Metrics) -> f64 {
    if shared.ipc() <= 0.0 {
        return f64::INFINITY;
    }
    solo.ipc() / shared.ipc()
}

/// Fairness aggregates over a cluster run's per-tenant metrics.
#[derive(Clone, Debug)]
pub struct Fairness {
    pub slowdowns: Vec<f64>,
    pub max_slowdown: f64,
    /// Unfairness index: max slowdown / min slowdown (1.0 = perfectly fair).
    pub unfairness: f64,
    /// Per-tenant p99 access cost in the shared run, cycles.
    pub p99_access_cost: Vec<f64>,
}

/// Compute fairness aggregates from per-tenant solo baselines and the
/// shared cluster run (index i = tenant i in both).
pub fn fairness(solo: &[Metrics], shared: &[Metrics]) -> Fairness {
    assert_eq!(solo.len(), shared.len(), "one solo baseline per tenant");
    assert!(!solo.is_empty(), "fairness needs at least one tenant");
    let slowdowns: Vec<f64> =
        solo.iter().zip(shared).map(|(s, sh)| slowdown(s, sh)).collect();
    let max = slowdowns.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let min = slowdowns.iter().cloned().fold(f64::INFINITY, f64::min);
    Fairness {
        max_slowdown: max,
        unfairness: if min > 0.0 { max / min } else { f64::INFINITY },
        p99_access_cost: shared.iter().map(Metrics::p99_access_cost).collect(),
        slowdowns,
    }
}

fn finite_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::num(v)
    } else {
        Json::Null
    }
}

fn jnum(j: &Json, key: &str) -> Result<f64, String> {
    j.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("metrics json: missing numeric field '{key}'"))
}

fn jint(j: &Json, key: &str) -> Result<u64, String> {
    Ok(jnum(j, key)? as u64)
}

/// min/max edges: serialized as null when the counter is empty.
fn jedge(j: &Json, key: &str, empty: f64) -> f64 {
    j.get(key).and_then(Json::as_f64).unwrap_or(empty)
}

fn jvec(j: &Json, key: &str) -> Result<Vec<u64>, String> {
    j.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("metrics json: missing array field '{key}'"))?
        .iter()
        .map(|v| {
            v.as_f64()
                .map(|f| f as u64)
                .ok_or_else(|| format!("metrics json: non-numeric entry in '{key}'"))
        })
        .collect()
}

fn jvec_f64(j: &Json, key: &str) -> Result<Vec<f64>, String> {
    j.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("metrics json: missing array field '{key}'"))?
        .iter()
        .map(|v| {
            v.as_f64()
                .ok_or_else(|| format!("metrics json: non-numeric entry in '{key}'"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ipc_and_hit_ratio() {
        let mut m = Metrics::new();
        m.instructions = 1000;
        m.cycles = 2000.0;
        assert!((m.ipc() - 0.5).abs() < 1e-12);
        m.local_hits = 9;
        m.local_misses = 1;
        assert!((m.local_hit_ratio() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = Metrics::new();
        assert_eq!(m.ipc(), 0.0);
        assert_eq!(m.local_hit_ratio(), 0.0);
        assert_eq!(m.mean_access_cost(), 0.0);
        assert_eq!(m.compression_ratio, 1.0);
        assert_eq!(m.goodput(), 0.0);
        assert_eq!(m.reclaimed_bytes, 0);
        assert_eq!(m.downtime_cycles, 0.0);
        assert_eq!(m.aborted_transfers, 0);
        assert_eq!(m.deferred_requests, 0);
        assert_eq!(m.controller_actuations, 0);
        assert!(m.net_util_series.is_empty());
        assert_eq!(m.requests_offered(), 0);
        assert_eq!(m.slo_goodput(), 0.0);
        assert_eq!(m.p99_request(), 0.0);
        assert_eq!(m.request_hist.total, 0);
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let mut m = Metrics::new();
        m.instructions = 123_456_789;
        m.cycles = 987_654.25;
        m.stall_cycles = 0.1 + 0.2; // not exactly representable in decimal
        m.access_cost.add(3.7);
        m.access_cost.add(1.2);
        m.local_hits = 10;
        m.local_misses = 3;
        m.pages_moved = 7;
        m.pages_throttled = 1;
        m.lines_moved = 9;
        m.writeback_bytes = 4096;
        m.net_bytes_in = 1 << 40;
        m.reclaimed_bytes = 123_456;
        m.downtime_cycles = 0.1 + 0.7; // not exactly representable
        m.aborted_transfers = 17;
        m.deferred_requests = 29;
        m.controller_actuations = 5;
        m.net_utilization = 1.0 / 3.0;
        m.net_util_series = vec![0.25, 1.0 / 7.0, 0.0, 0.99];
        m.compression_ratio = 2.39;
        m.bump_interval(0, 5);
        m.bump_interval_local(2, true);
        m.requests_completed = 118;
        m.requests_timed_out = 3;
        m.requests_shed = 11;
        m.request_retries = 9;
        m.request_hedges = 6;
        m.request_hedge_wins = 2;
        m.requests_slo_good = 101;
        m.request_hist.add(150_000.0);
        m.request_hist.add(90.0);
        let s = m.to_json().to_string();
        let back = Metrics::from_json(&Json::parse(&s).unwrap()).unwrap();
        assert_eq!(s, back.to_json().to_string(), "round-trip must be stable");
        assert_eq!(back.instructions, m.instructions);
        assert_eq!(back.cycles.to_bits(), m.cycles.to_bits());
        assert_eq!(back.stall_cycles.to_bits(), m.stall_cycles.to_bits());
        assert_eq!(back.access_cost.n, 2);
        assert_eq!(back.mean_access_cost(), m.mean_access_cost());
        assert_eq!(back.interval_instructions, m.interval_instructions);
        assert_eq!(back.hit_ratio_series(), m.hit_ratio_series());
        assert_eq!(back.reclaimed_bytes, m.reclaimed_bytes);
        assert_eq!(back.downtime_cycles.to_bits(), m.downtime_cycles.to_bits());
        assert_eq!(back.aborted_transfers, m.aborted_transfers);
        assert_eq!(back.deferred_requests, m.deferred_requests);
        assert_eq!(back.controller_actuations, m.controller_actuations);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back.net_util_series), bits(&m.net_util_series));
        assert_eq!(back.goodput().to_bits(), m.goodput().to_bits());
        assert_eq!(back.requests_completed, m.requests_completed);
        assert_eq!(back.requests_offered(), m.requests_offered());
        assert_eq!(back.requests_slo_good, m.requests_slo_good);
        assert_eq!(back.request_hedge_wins, m.request_hedge_wins);
        assert_eq!(back.request_hist, m.request_hist);
        assert_eq!(back.slo_goodput().to_bits(), m.slo_goodput().to_bits());
        assert_eq!(back.p99_request().to_bits(), m.p99_request().to_bits());
    }

    #[test]
    fn json_roundtrip_handles_empty_running_counter() {
        let e = Metrics::new();
        let back = Metrics::from_json(&Json::parse(&e.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(back.access_cost.n, 0);
        assert_eq!(back.access_cost.min, f64::INFINITY);
        assert_eq!(back.access_cost.max, f64::NEG_INFINITY);
        assert_eq!(back.mean_access_cost(), 0.0);
    }

    #[test]
    fn access_hist_roundtrips_and_feeds_p99() {
        let mut m = Metrics::new();
        for _ in 0..99 {
            m.access_hist.add(100.0); // bucket [64, 128)
        }
        m.access_hist.add(3000.0); // bucket [2048, 4096)
        assert!((m.p99_access_cost() - 96.0).abs() < 1e-9, "{}", m.p99_access_cost());
        let back =
            Metrics::from_json(&Json::parse(&m.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.access_hist, m.access_hist);
        assert_eq!(back.p99_access_cost(), m.p99_access_cost());
    }

    #[test]
    fn fairness_aggregates() {
        let mk = |instr: u64, cycles: f64| {
            let mut m = Metrics::new();
            m.instructions = instr;
            m.cycles = cycles;
            m
        };
        // Tenant 0 slows 2x, tenant 1 slows 4x.
        let solo = vec![mk(1000, 1000.0), mk(1000, 1000.0)];
        let shared = vec![mk(1000, 2000.0), mk(1000, 4000.0)];
        let f = fairness(&solo, &shared);
        assert!((f.slowdowns[0] - 2.0).abs() < 1e-12);
        assert!((f.slowdowns[1] - 4.0).abs() < 1e-12);
        assert!((f.max_slowdown - 4.0).abs() < 1e-12);
        assert!((f.unfairness - 2.0).abs() < 1e-12);
        assert_eq!(f.p99_access_cost.len(), 2);
        assert_eq!(slowdown(&solo[0], &mk(1000, 0.0)), f64::INFINITY);
    }

    #[test]
    fn interval_series() {
        let mut m = Metrics::new();
        m.bump_interval(0, 100);
        m.bump_interval(2, 300);
        m.bump_interval_local(2, true);
        m.bump_interval_local(2, false);
        assert_eq!(m.ipc_series(100.0), vec![1.0, 0.0, 3.0]);
        assert_eq!(m.hit_ratio_series()[2], 0.5);
    }
}
