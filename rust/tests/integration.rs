//! Cross-module integration tests: whole-machine invariants that hold for
//! every scheme, workload class and configuration.

use daemon_sim::config::{NetConfig, Replacement, SimConfig};
use daemon_sim::schemes::SchemeKind;
use daemon_sim::system::{run_workload, Machine};
use daemon_sim::util::stats::geomean;
use daemon_sim::workloads::{by_name, Scale};

fn cfg() -> SimConfig {
    SimConfig::test_scale().with_seed(99)
}

fn ipc(kind: SchemeKind, wl: &str, cfg: &SimConfig) -> f64 {
    let w = by_name(wl).unwrap();
    run_workload(cfg, kind, w.as_ref(), Scale::Test).metrics.ipc()
}

const ALL_SCHEMES: [SchemeKind; 9] = [
    SchemeKind::Local,
    SchemeKind::CacheLine,
    SchemeKind::Remote,
    SchemeKind::PageFree,
    SchemeKind::CacheLinePage,
    SchemeKind::Lc,
    SchemeKind::Bp,
    SchemeKind::Pq,
    SchemeKind::Daemon,
];

#[test]
fn every_scheme_completes_every_class() {
    // One workload per locality class through all nine schemes.
    for wl in ["pr", "bf", "sp"] {
        for kind in ALL_SCHEMES {
            let c = cfg();
            let v = ipc(kind, wl, &c);
            assert!(v > 0.0, "{wl}/{}: zero IPC", kind.name());
            assert!(v < 4.1, "{wl}/{}: IPC {v} above issue width", kind.name());
        }
    }
}

#[test]
fn instructions_are_scheme_invariant() {
    // The committed instruction count is a property of the trace alone.
    let w = by_name("ts").unwrap();
    let c = cfg();
    let counts: Vec<u64> = ALL_SCHEMES
        .iter()
        .map(|&k| run_workload(&c, k, w.as_ref(), Scale::Test).metrics.instructions)
        .collect();
    for v in &counts {
        assert_eq!(*v, counts[0]);
    }
}

#[test]
fn local_dominates_all_remote_schemes() {
    for wl in ["pr", "sp"] {
        let c = cfg();
        let local = ipc(SchemeKind::Local, wl, &c);
        for kind in [SchemeKind::Remote, SchemeKind::Lc, SchemeKind::Pq, SchemeKind::Daemon] {
            let v = ipc(kind, wl, &c);
            assert!(
                v <= local * 1.05,
                "{wl}/{}: {v} exceeds Local {local}",
                kind.name()
            );
        }
    }
}

#[test]
fn daemon_is_robust_across_network_grid() {
    // DaeMon must never lose badly to Remote at any operating point —
    // the paper's robustness claim.
    let w = by_name("bf").unwrap();
    let mut ratios = Vec::new();
    for sw in [100.0, 400.0] {
        for bw in [2.0, 8.0] {
            let c = cfg().with_net(sw, bw);
            let remote = run_workload(&c, SchemeKind::Remote, w.as_ref(), Scale::Test);
            let daemon = run_workload(&c, SchemeKind::Daemon, w.as_ref(), Scale::Test);
            let ratio = daemon.metrics.ipc() / remote.metrics.ipc();
            assert!(ratio > 0.8, "DaeMon lost at {sw}ns 1/{bw}: {ratio}");
            ratios.push(ratio);
        }
    }
    assert!(geomean(&ratios) > 1.0, "no net win across the grid");
}

#[test]
fn tighter_bandwidth_hurts_remote_more_than_daemon() {
    let w = by_name("sp").unwrap();
    let wide = cfg().with_net(100.0, 2.0);
    let narrow = cfg().with_net(100.0, 8.0);
    let r_wide = run_workload(&wide, SchemeKind::Remote, w.as_ref(), Scale::Test).metrics.ipc();
    let r_narrow = run_workload(&narrow, SchemeKind::Remote, w.as_ref(), Scale::Test).metrics.ipc();
    let d_wide = run_workload(&wide, SchemeKind::Daemon, w.as_ref(), Scale::Test).metrics.ipc();
    let d_narrow = run_workload(&narrow, SchemeKind::Daemon, w.as_ref(), Scale::Test).metrics.ipc();
    let remote_drop = r_wide / r_narrow;
    let daemon_drop = d_wide / d_narrow;
    assert!(
        remote_drop > daemon_drop * 0.95,
        "Remote drop {remote_drop} vs DaeMon drop {daemon_drop}"
    );
}

#[test]
fn compression_moves_fewer_bytes() {
    let w = by_name("sp").unwrap();
    let c = cfg();
    let pq = run_workload(&c, SchemeKind::Pq, w.as_ref(), Scale::Test);
    let dm = run_workload(&c, SchemeKind::Daemon, w.as_ref(), Scale::Test);
    // Comparable page counts, far fewer bytes on the wire.
    assert!(
        (dm.metrics.net_bytes_in as f64)
            < pq.metrics.net_bytes_in as f64 * 0.8,
        "DaeMon {} vs PQ {} bytes",
        dm.metrics.net_bytes_in,
        pq.metrics.net_bytes_in
    );
    assert!(dm.metrics.compression_ratio > 1.5);
}

#[test]
fn fifo_and_lru_both_work_and_lru_wins_on_reuse() {
    let w = by_name("sl").unwrap(); // Zipf reuse: LRU should help
    let lru = cfg();
    let fifo = cfg().with_replacement(Replacement::Fifo);
    let m_lru = run_workload(&lru, SchemeKind::Remote, w.as_ref(), Scale::Test);
    let m_fifo = run_workload(&fifo, SchemeKind::Remote, w.as_ref(), Scale::Test);
    assert!(
        m_lru.metrics.local_hit_ratio() >= m_fifo.metrics.local_hit_ratio() - 0.02,
        "LRU {} vs FIFO {}",
        m_lru.metrics.local_hit_ratio(),
        m_fifo.metrics.local_hit_ratio()
    );
}

#[test]
fn multiple_memory_components_are_deterministic_and_faster() {
    let w = by_name("pr").unwrap();
    let c4 = cfg().with_memory_components(vec![NetConfig::new(100.0, 4.0); 4]);
    let a = run_workload(&c4, SchemeKind::Daemon, w.as_ref(), Scale::Test);
    let b = run_workload(&c4, SchemeKind::Daemon, w.as_ref(), Scale::Test);
    assert_eq!(a.metrics.instructions, b.metrics.instructions);
    assert!((a.metrics.cycles - b.metrics.cycles).abs() < 1e-6, "nondeterminism");
    let c1 = cfg();
    let one = run_workload(&c1, SchemeKind::Daemon, w.as_ref(), Scale::Test);
    assert!(a.metrics.ipc() >= one.metrics.ipc() * 0.95);
}

#[test]
fn random_placement_matches_round_robin_in_shape() {
    let w = by_name("pr").unwrap();
    let mut rr = cfg().with_memory_components(vec![NetConfig::new(100.0, 4.0); 4]);
    rr.placement_round_robin = true;
    let mut rand = rr.clone();
    rand.placement_round_robin = false;
    let m_rr = run_workload(&rr, SchemeKind::Daemon, w.as_ref(), Scale::Test);
    let m_rand = run_workload(&rand, SchemeKind::Daemon, w.as_ref(), Scale::Test);
    let ratio = m_rr.metrics.ipc() / m_rand.metrics.ipc();
    assert!((0.7..1.4).contains(&ratio), "placement sensitivity {ratio}");
}

#[test]
fn partition_ratio_extremes_behave() {
    let w = by_name("pr").unwrap();
    for ratio in [0.05, 0.5, 0.9] {
        let c = cfg().with_partition_ratio(ratio);
        let m = run_workload(&c, SchemeKind::Daemon, w.as_ref(), Scale::Test);
        assert!(m.metrics.ipc() > 0.0, "ratio {ratio} wedged");
    }
}

#[test]
fn page_free_bounds_all_page_schemes() {
    // The Fig. 3 idealization is an upper bound for every page-moving
    // remote scheme.
    let c = cfg();
    for wl in ["pr", "sp"] {
        let pf = ipc(SchemeKind::PageFree, wl, &c);
        for kind in [SchemeKind::Remote, SchemeKind::Lc, SchemeKind::Daemon] {
            let v = ipc(kind, wl, &c);
            assert!(
                v <= pf * 1.1,
                "{wl}/{}: {v} above page-free bound {pf}",
                kind.name()
            );
        }
    }
}

#[test]
fn writebacks_happen_for_write_heavy_workloads() {
    let w = by_name("nw").unwrap(); // store per DP cell
    let c = cfg();
    let m = run_workload(&c, SchemeKind::Daemon, w.as_ref(), Scale::Test);
    assert!(m.metrics.writeback_bytes > 0, "no dirty data written back");
}

#[test]
fn multicore_work_conservation() {
    // 4 cores running the same trace commit 4x the instructions and lose
    // per-core throughput to shared-resource contention.
    let w = by_name("ts").unwrap();
    let c1 = cfg();
    let c4 = cfg().with_cores(4);
    let one = run_workload(&c1, SchemeKind::Daemon, w.as_ref(), Scale::Test);
    let four = run_workload(&c4, SchemeKind::Daemon, w.as_ref(), Scale::Test);
    assert_eq!(four.metrics.instructions, 4 * one.metrics.instructions);
    let per_core = four.metrics.ipc() / 4.0;
    assert!(per_core <= one.metrics.ipc() * 1.05);
}

#[test]
fn interval_series_cover_the_run() {
    let w = by_name("pr").unwrap();
    let c = cfg();
    let trace = w.generate(c.seed, Scale::Test);
    let mut m = Machine::new(
        c.clone(),
        SchemeKind::Daemon,
        trace.footprint_pages,
        vec![w.profile()],
        None,
    );
    m.run(std::slice::from_ref(&trace));
    let series = m.metrics.ipc_series(daemon_sim::config::ns_to_cycles(c.interval_ns));
    let total: f64 = series.iter().sum::<f64>()
        * daemon_sim::config::ns_to_cycles(c.interval_ns);
    let rel = (total - m.metrics.instructions as f64).abs()
        / m.metrics.instructions as f64;
    assert!(rel < 0.05, "interval series lose instructions: {rel}");
}
