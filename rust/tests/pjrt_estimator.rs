//! Integration tests across the AOT boundary: the HLO artifact executed
//! through PJRT must (a) agree bit-closely with the native rust mirror of
//! the L1 kernel formula, (b) correlate with the real LZ77 compressor,
//! and (c) drive the full simulator as a drop-in SizeOracle.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use daemon_sim::compress::{est, lz, synth};
use daemon_sim::config::SimConfig;
use daemon_sim::runtime::{ModelRunner, NetParams, PjrtOracle, AOT_BATCH, WORDS_PER_PAGE};
use daemon_sim::schemes::SchemeKind;
use daemon_sim::system::{Machine, SizeOracle};
use daemon_sim::util::prng::Rng;
use daemon_sim::util::stats::pearson;
use daemon_sim::workloads::{by_name, Scale};

fn runner_or_skip() -> Option<ModelRunner> {
    match ModelRunner::load_default() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            None
        }
    }
}

fn batch_pages(seed: u64, profile: synth::Profile) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    let mut words = Vec::with_capacity(AOT_BATCH * WORDS_PER_PAGE);
    for _ in 0..AOT_BATCH {
        words.extend_from_slice(&synth::gen_page_words(&mut rng, profile));
    }
    words
}

#[test]
fn pjrt_matches_native_estimator_mirror() {
    let Some(runner) = runner_or_skip() else { return };
    for (seed, profile) in [
        (1u64, synth::Profile::high()),
        (2, synth::Profile::medium()),
        (3, synth::Profile::low()),
    ] {
        let words = batch_pages(seed, profile);
        let out = runner.run_batch(&words, NetParams::paper_default()).unwrap();
        for i in 0..AOT_BATCH {
            let page = &words[i * WORDS_PER_PAGE..(i + 1) * WORDS_PER_PAGE];
            let native = est::estimate_page(page);
            for a in 0..3 {
                let got = out.est_bytes[i][a];
                let want = native[a];
                assert!(
                    (got - want).abs() <= 0.5 + want.abs() * 1e-5,
                    "batch {i} algo {a}: pjrt {got} vs native {want}"
                );
            }
        }
    }
}

#[test]
fn pjrt_estimates_correlate_with_real_lz() {
    let Some(runner) = runner_or_skip() else { return };
    let mut est_sizes = Vec::new();
    let mut real_sizes = Vec::new();
    for (seed, mix) in [(10u64, 0.1), (11, 0.4), (12, 0.7), (13, 0.95)] {
        let profile = synth::Profile::uniform_mix(mix);
        let words = batch_pages(seed, profile);
        let out = runner.run_batch(&words, NetParams::paper_default()).unwrap();
        for i in 0..AOT_BATCH {
            let page_words = &words[i * WORDS_PER_PAGE..(i + 1) * WORDS_PER_PAGE];
            let mut bytes = Vec::with_capacity(4096);
            for w in page_words {
                bytes.extend_from_slice(&w.to_le_bytes());
            }
            est_sizes.push(out.est_bytes[i][0] as f64);
            real_sizes.push(lz::compressed_size(&bytes) as f64);
        }
    }
    let r = pearson(&est_sizes, &real_sizes);
    assert!(r > 0.85, "PJRT estimator vs real LZ correlation {r}");
}

#[test]
fn cost_model_semantics() {
    let Some(runner) = runner_or_skip() else { return };
    let words = batch_pages(20, synth::Profile::high());
    let p = NetParams::paper_default();
    let out = runner.run_batch(&words, p).unwrap();
    // Lines beat pages at the default operating point.
    for i in 0..AOT_BATCH {
        assert!(out.line_cycles[i] < out.page_cycles[i]);
        assert!(out.advantage[i] > 0.0);
    }
    // Raising the partition ratio speeds lines and slows pages.
    let p80 = NetParams { partition_ratio: 0.8, ..p };
    let out80 = runner.run_batch(&words, p80).unwrap();
    assert!(out80.line_cycles[0] < out.line_cycles[0]);
    assert!(out80.page_cycles[0] > out.page_cycles[0]);
}

#[test]
fn pjrt_oracle_drives_full_simulation() {
    let Some(runner) = runner_or_skip() else { return };
    let w = by_name("sp").unwrap();
    let cfg = SimConfig::test_scale().with_seed(7);
    let trace = w.generate(cfg.seed, Scale::Test);

    // PJRT-backed run.
    let oracle = PjrtOracle::new(
        runner,
        NetParams::paper_default(),
        cfg.seed,
        vec![w.profile()],
    );
    let mut m = Machine::new(
        cfg.clone(),
        SchemeKind::Daemon,
        trace.footprint_pages,
        vec![w.profile()],
        Some(Box::new(oracle)),
    );
    m.run(std::slice::from_ref(&trace));
    let pjrt_ipc = m.metrics.ipc();
    let pjrt_ratio = m.metrics.compression_ratio;

    // Exact-oracle run.
    let mut m2 = Machine::new(
        cfg.clone(),
        SchemeKind::Daemon,
        trace.footprint_pages,
        vec![w.profile()],
        None,
    );
    m2.run(std::slice::from_ref(&trace));
    let exact_ipc = m2.metrics.ipc();
    let exact_ratio = m2.metrics.compression_ratio;

    assert!(pjrt_ipc > 0.0 && exact_ipc > 0.0);
    // The estimator tracks the real compressor closely enough that the
    // end-to-end results agree within 25%.
    let ipc_rel = (pjrt_ipc - exact_ipc).abs() / exact_ipc;
    assert!(ipc_rel < 0.25, "IPC divergence {ipc_rel} (pjrt {pjrt_ipc} vs exact {exact_ipc})");
    // The estimator over-credits extremely structured pages (its role is
    // granularity adaptivity, not exact sizing — the exact oracle remains
    // the default), so the achieved-ratio agreement bound is loose.
    let ratio_rel = (pjrt_ratio - exact_ratio).abs() / exact_ratio;
    assert!(
        ratio_rel < 0.8,
        "ratio divergence {ratio_rel} (pjrt {pjrt_ratio} vs exact {exact_ratio})"
    );
}

#[test]
fn oracle_batches_amortize_dispatches() {
    let Some(runner) = runner_or_skip() else { return };
    let mut oracle = PjrtOracle::new(
        runner,
        NetParams::paper_default(),
        42,
        vec![synth::Profile::medium()],
    );
    // 64 consecutive pages must be served by a single batch.
    for p in 1000..1000 + AOT_BATCH as u64 {
        let _ = oracle.page_size(0, p);
    }
    assert_eq!(oracle.batches_run, 1, "expected one batched dispatch");
    assert!(oracle.ratio() > 1.0);
}
