//! Property tests pinning every shipped lifecycle to its DESIGN.md
//! transition table (§"Lifecycles and state machines").
//!
//! Three checks per machine, all driven by the `lifecycle` core:
//!
//! * `check_declaration` — states/events/names unique, table endpoints
//!   declared, no ambiguous `(from, event)` rows;
//! * `assert_graph_matches_doc` — the `TABLE` const and the DESIGN.md
//!   table under the machine's heading are the same edge set (no
//!   undeclared transitions in either direction, no duplicates);
//! * `exercise_graph` — generated traces (`util::proptest`) drive a
//!   real `StateMachine` along declared edges only and must cover every
//!   edge reachable from the initial state, which also proves terminal
//!   states are absorbing (they have no declared edges to drive).

use daemon_sim::daemon::{LineLifecycle, PageLifecycle};
use daemon_sim::lifecycle::{assert_graph_matches_doc, check_declaration, exercise_graph};
use daemon_sim::system::fault::PortState;
use daemon_sim::system::{RequestState, TenantState};

fn design() -> String {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/DESIGN.md");
    std::fs::read_to_string(path).expect("read DESIGN.md")
}

#[test]
fn engine_page_lifecycle_matches_design_doc() {
    check_declaration::<PageLifecycle>();
    assert_graph_matches_doc::<PageLifecycle>(&design(), "### Compute-engine page lifecycle");
    exercise_graph(0xDAE0_0001, PageLifecycle::Scheduled);
}

#[test]
fn engine_line_lifecycle_matches_design_doc() {
    check_declaration::<LineLifecycle>();
    assert_graph_matches_doc::<LineLifecycle>(&design(), "### Compute-engine line lifecycle");
    exercise_graph(0xDAE0_0002, LineLifecycle::Inflight);
}

#[test]
fn fabric_port_lifecycle_matches_design_doc() {
    check_declaration::<PortState>();
    assert_graph_matches_doc::<PortState>(&design(), "### Fabric port lifecycle");
    exercise_graph(0xDAE0_0003, PortState::Up);
}

#[test]
fn cluster_tenant_lifecycle_matches_design_doc() {
    check_declaration::<TenantState>();
    assert_graph_matches_doc::<TenantState>(&design(), "### Cluster tenant lifecycle");
    exercise_graph(0xDAE0_0004, TenantState::Running);
}

#[test]
fn service_request_lifecycle_matches_design_doc() {
    check_declaration::<RequestState>();
    assert_graph_matches_doc::<RequestState>(&design(), "### Request lifecycle");
    exercise_graph(0xDAE0_0005, RequestState::Admitted);
}
