//! Determinism pins for the hot-path data structures (sharded size memo,
//! Fx-hashed tables, O(1) LRU, heap-driven stepping): the same cell must
//! produce byte-identical `Metrics::to_json` output on repeat runs of the
//! same process and under any `--jobs` worker count.  Map iteration order
//! and memo fill order must never reach the metrics — see DESIGN.md
//! §"Simulator performance model".

use daemon_sim::config::SimConfig;
use daemon_sim::experiments::orchestrator::{run_cells_flat, CellSpec, Shard};
use daemon_sim::experiments::Runner;
use daemon_sim::metrics::Metrics;
use daemon_sim::schemes::SchemeKind;
use daemon_sim::system::Machine;
use daemon_sim::workloads::cache::TraceCache;
use daemon_sim::workloads::{by_name, Scale};

fn run_once(kind: SchemeKind) -> String {
    let w = by_name("pr").unwrap();
    let cfg = SimConfig::test_scale().with_seed(11);
    let trace = w.generate(cfg.seed, Scale::Test);
    let mut m = Machine::new(
        cfg,
        kind,
        trace.footprint_pages,
        vec![w.profile()],
        None,
    );
    m.run(std::slice::from_ref(&trace));
    m.metrics.to_json().to_string()
}

#[test]
fn pq_and_daemon_repeat_runs_are_byte_identical() {
    // Two full runs of the same trace in one process: the second run hits
    // the process-global size memo the first run populated, plus every
    // Fx-hashed table and the new LRU — none of which may perturb a
    // single metric byte.
    for kind in [SchemeKind::Pq, SchemeKind::Daemon] {
        let a = run_once(kind);
        let b = run_once(kind);
        assert_eq!(a, b, "{kind:?}: repeat run diverged");
    }
}

/// The `--jobs 4` determinism pin: pq + daemon (+ lc, the heaviest user
/// of the shared compressed-size memo) cells over shared traces must
/// produce byte-identical metrics whether one worker fills the sharded
/// memo serially or four workers race it.
#[test]
fn jobs_4_matches_jobs_1_byte_identically() {
    let r = Runner::test();
    let cells: Vec<CellSpec> = ["pr", "sp"]
        .into_iter()
        .flat_map(|wl| {
            [SchemeKind::Pq, SchemeKind::Daemon, SchemeKind::Lc]
                .into_iter()
                .map(move |k| CellSpec::new(wl, k, SimConfig::test_scale()))
        })
        .collect();
    let fmt = |slots: Vec<Option<Vec<Metrics>>>| -> Vec<String> {
        slots
            .into_iter()
            .map(|s| {
                s.expect("unsharded run fills every slot")
                    .iter()
                    .map(|m| m.to_json().to_string())
                    .collect::<Vec<_>>()
                    .join("\n")
            })
            .collect()
    };
    let serial = fmt(run_cells_flat(&r, &TraceCache::new(), &cells, Shard::full(), 1));
    let racing = fmt(run_cells_flat(&r, &TraceCache::new(), &cells, Shard::full(), 4));
    assert_eq!(serial, racing, "--jobs 4 diverged from --jobs 1");
    // And a second racing pass over the now-warm global memo.
    let warm = fmt(run_cells_flat(&r, &TraceCache::new(), &cells, Shard::full(), 4));
    assert_eq!(serial, warm, "warm-memo rerun diverged");
}
