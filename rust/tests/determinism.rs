//! Determinism pins for the hot-path data structures (sharded size memo,
//! Fx-hashed tables, O(1) LRU, heap-driven stepping): the same cell must
//! produce byte-identical `Metrics::to_json` output on repeat runs of the
//! same process and under any `--jobs` worker count.  Map iteration order
//! and memo fill order must never reach the metrics — see DESIGN.md
//! §"Simulator performance model".

use daemon_sim::config::SimConfig;
use daemon_sim::experiments::orchestrator::{
    run_cells_flat, run_cells_flat_obs, CellSpec, Shard,
};
use daemon_sim::experiments::Runner;
use daemon_sim::metrics::Metrics;
use daemon_sim::obs::{chrome_trace, telemetry_jsonl, Event, ObsSpec, Recorder};
use daemon_sim::schemes::SchemeKind;
use daemon_sim::system::Machine;
use daemon_sim::workloads::cache::TraceCache;
use daemon_sim::workloads::{by_name, Scale};

fn run_once_obs(kind: SchemeKind, obs: Option<ObsSpec>) -> (String, Option<Recorder>) {
    let w = by_name("pr").unwrap();
    let cfg = SimConfig::test_scale().with_seed(11);
    let trace = w.generate(cfg.seed, Scale::Test);
    let mut m = Machine::new(
        cfg,
        kind,
        trace.footprint_pages,
        vec![w.profile()],
        None,
    );
    if let Some(spec) = obs {
        m.set_obs(Recorder::new(spec));
    }
    m.run(std::slice::from_ref(&trace));
    (m.metrics.to_json().to_string(), m.take_obs())
}

fn run_once(kind: SchemeKind) -> String {
    run_once_obs(kind, None).0
}

#[test]
fn pq_and_daemon_repeat_runs_are_byte_identical() {
    // Two full runs of the same trace in one process: the second run hits
    // the process-global size memo the first run populated, plus every
    // Fx-hashed table and the new LRU — none of which may perturb a
    // single metric byte.
    for kind in [SchemeKind::Pq, SchemeKind::Daemon] {
        let a = run_once(kind);
        let b = run_once(kind);
        assert_eq!(a, b, "{kind:?}: repeat run diverged");
    }
}

/// The `--jobs 4` determinism pin: pq + daemon (+ lc, the heaviest user
/// of the shared compressed-size memo) cells over shared traces must
/// produce byte-identical metrics whether one worker fills the sharded
/// memo serially or four workers race it.
#[test]
fn jobs_4_matches_jobs_1_byte_identically() {
    let r = Runner::test();
    let cells: Vec<CellSpec> = ["pr", "sp"]
        .into_iter()
        .flat_map(|wl| {
            [SchemeKind::Pq, SchemeKind::Daemon, SchemeKind::Lc]
                .into_iter()
                .map(move |k| CellSpec::new(wl, k, SimConfig::test_scale()))
        })
        .collect();
    let fmt = |slots: Vec<Option<Vec<Metrics>>>| -> Vec<String> {
        slots
            .into_iter()
            .map(|s| {
                s.expect("unsharded run fills every slot")
                    .iter()
                    .map(|m| m.to_json().to_string())
                    .collect::<Vec<_>>()
                    .join("\n")
            })
            .collect()
    };
    let serial = fmt(run_cells_flat(&r, &TraceCache::new(), &cells, Shard::full(), 1));
    let racing = fmt(run_cells_flat(&r, &TraceCache::new(), &cells, Shard::full(), 4));
    assert_eq!(serial, racing, "--jobs 4 diverged from --jobs 1");
    // And a second racing pass over the now-warm global memo.
    let warm = fmt(run_cells_flat(&r, &TraceCache::new(), &cells, Shard::full(), 4));
    assert_eq!(serial, warm, "warm-memo rerun diverged");
}

/// The observability off/on pin: attaching a recorder must not perturb a
/// single metric byte.  Every sampled accessor takes `&self`, so this is
/// true by construction — this test keeps it true under refactoring.
#[test]
fn attaching_a_recorder_never_perturbs_metrics() {
    for kind in [SchemeKind::Daemon, SchemeKind::Pq] {
        let (plain, _) = run_once_obs(kind, None);
        let spec = ObsSpec::enabled().with_epoch(5_000.0);
        let (observed, rec) = run_once_obs(kind, Some(spec));
        assert_eq!(plain, observed, "{kind:?}: recorder changed the metrics");
        let rec = rec.expect("recorder survives the run");
        assert!(
            !rec.telemetry.snapshots.is_empty(),
            "{kind:?}: epoch sampling (plus the forced horizon sample) \
             must produce snapshots"
        );
        assert!(
            !rec.trace.is_empty(),
            "{kind:?}: page-moving schemes must log trace events"
        );
    }
}

/// Observability artifacts are part of the determinism contract: the
/// serialized telemetry JSONL and Chrome trace must be byte-identical
/// across `--jobs 1` vs `--jobs 4` and across repeat runs.
#[test]
fn obs_artifacts_are_jobs_invariant_and_repeatable() {
    let r = Runner::test();
    let cells: Vec<CellSpec> = ["pr", "sp"]
        .into_iter()
        .flat_map(|wl| {
            [SchemeKind::Daemon, SchemeKind::Pq]
                .into_iter()
                .map(move |k| CellSpec::new(wl, k, SimConfig::test_scale()))
        })
        .collect();
    let spec = ObsSpec::enabled().with_epoch(10_000.0);
    let export = |jobs: usize| -> (String, String) {
        let slots = run_cells_flat_obs(
            &r,
            &TraceCache::new(),
            &cells,
            Shard::full(),
            jobs,
            Some(&spec),
            None,
        );
        let owned: Vec<(String, Vec<Recorder>)> = slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| {
                let (_, recs) = s.expect("unsharded run fills every slot");
                (format!("cell/{i}"), recs)
            })
            .collect();
        let cells_ref: Vec<(String, Vec<&Recorder>)> = owned
            .iter()
            .map(|(l, rs)| (l.clone(), rs.iter().collect()))
            .collect();
        (telemetry_jsonl(&cells_ref), chrome_trace(&cells_ref).to_string())
    };
    let (t1, c1) = export(1);
    assert!(!t1.is_empty(), "telemetry must not be empty");
    let (t4, c4) = export(4);
    assert_eq!(t1, t4, "telemetry diverged across --jobs counts");
    assert_eq!(c1, c4, "chrome trace diverged across --jobs counts");
    let (t1b, c1b) = export(1);
    assert_eq!(t1, t1b, "telemetry diverged across repeat runs");
    assert_eq!(c1, c1b, "chrome trace diverged across repeat runs");
}

/// Repeat-run byte-identity pins on the two cluster scenario sweeps the
/// lifecycle/policy refactor leans on most: a faulted `resilience` cell
/// (fault timeline replayed through the `PortState` machine, `Refetch`
/// routing resolved via the policy registry, a tenant kill driven
/// through the `TenantState` machine) and a `variability` cell (sharing
/// discipline looked up from `policy::SHARING` on the work-conserving
/// borrow path).
#[test]
fn resilience_and_variability_cells_repeat_byte_identically() {
    use daemon_sim::config::{ScheduleSpec, SharingMode};
    use daemon_sim::experiments::{resilience, variability};
    use daemon_sim::system::fault::{FaultPlan, RecoveryPolicy};

    let r = Runner::test();
    let plan = FaultPlan::new().module_crash(1, 2e5, 6e5).tenant_kill(3, 8e5);
    let sched = ScheduleSpec {
        period_cycles: 1e5,
        rate_scale: 0.5,
        extra_latency_ns: 100.0,
        horizon_cycles: 1e9,
    };
    let cells = vec![
        resilience::cell(
            SchemeKind::Daemon,
            Some(plan),
            RecoveryPolicy::Refetch,
            SimConfig::test_scale(),
        ),
        variability::cell(
            SchemeKind::Pq,
            SharingMode::WorkConserving,
            Some(sched),
            SimConfig::test_scale(),
        ),
    ];
    let fmt = |slots: Vec<Option<Vec<Metrics>>>| -> Vec<String> {
        slots
            .into_iter()
            .map(|s| {
                s.expect("unsharded run fills every slot")
                    .iter()
                    .map(|m| m.to_json().to_string())
                    .collect::<Vec<_>>()
                    .join("\n")
            })
            .collect()
    };
    let a = fmt(run_cells_flat(&r, &TraceCache::new(), &cells, Shard::full(), 1));
    let b = fmt(run_cells_flat(&r, &TraceCache::new(), &cells, Shard::full(), 1));
    assert_eq!(a, b, "scenario cells diverged across repeat runs");
}

/// Closed-loop `adaptive` pins: the controller is a pure function of
/// state sampled on the deterministic epoch grid, so closed-loop cells
/// must be byte-identical across repeat runs and `--jobs 1` vs
/// `--jobs 4`; and an inert controller spec (epoch 0) must be
/// byte-identical to the corresponding static cell.
#[test]
fn adaptive_cells_are_jobs_invariant_and_inert_specs_match_static() {
    use daemon_sim::config::ControllerSpec;
    use daemon_sim::experiments::adaptive::{arms, cell, conditions};

    let r = Runner::test();
    let (_, sched, _) = conditions().remove(1); // bw-burst: the loop actuates
    let all = arms();
    let closed = *all.iter().find(|a| a.name == "closed-loop").unwrap();
    let daemon = *all.iter().find(|a| a.name == "daemon").unwrap();
    let cells = vec![
        cell(&closed, sched, None, SimConfig::test_scale()),
        cell(&daemon, sched, None, SimConfig::test_scale()),
    ];
    let run = |jobs: usize| -> Vec<Vec<Metrics>> {
        run_cells_flat(&r, &TraceCache::new(), &cells, Shard::full(), jobs)
            .into_iter()
            .map(|s| s.expect("unsharded run fills every slot"))
            .collect()
    };
    let fmt = |slots: &[Vec<Metrics>]| -> Vec<String> {
        slots
            .iter()
            .map(|ms| {
                ms.iter().map(|m| m.to_json().to_string()).collect::<Vec<_>>().join("\n")
            })
            .collect()
    };
    let serial = run(1);
    let acts = |ms: &[Metrics]| ms.iter().map(|m| m.controller_actuations).sum::<u64>();
    assert!(acts(&serial[0]) > 0, "closed-loop cell never actuated — pins nothing");
    assert_eq!(acts(&serial[1]), 0, "static cell must never actuate");
    assert_eq!(
        fmt(&serial),
        fmt(&run(4)),
        "adaptive cells diverged across --jobs counts"
    );
    assert_eq!(fmt(&serial), fmt(&run(1)), "adaptive cells diverged on repeat");

    let mut inert = cell(&daemon, sched, None, SimConfig::test_scale());
    inert.cluster.as_mut().unwrap().controller = Some(ControllerSpec::all(0.0));
    let slots = run_cells_flat(
        &r,
        &TraceCache::new(),
        std::slice::from_ref(&inert),
        Shard::full(),
        1,
    );
    let inert_ms = slots.into_iter().next().unwrap().expect("slot filled");
    assert_eq!(
        fmt(std::slice::from_ref(&inert_ms))[0],
        fmt(&serial)[1],
        "inert controller spec perturbed a static cell"
    );
}

/// Request-serving pins: service cells (the full robustness stack under
/// a mid-run module crash) must be byte-identical across repeat runs
/// and `--jobs 1` vs `--jobs 4` — the front-end orders every decision
/// by `(sim cycle, sequence)` in one heap, so worker scheduling never
/// reaches the ledger.  A no-service cluster cell rides along: with
/// `service: None` the orchestrator takes the exact historical
/// trace-driven path (a single `Option` check), and its bytes must be
/// equally invariant.
#[test]
fn service_cells_are_jobs_invariant_and_repeat_byte_identically() {
    use daemon_sim::config::{ArrivalPattern, ServiceSpec};
    use daemon_sim::experiments::tail_latency;
    use daemon_sim::system::fault::FaultPlan;

    let r = Runner::test();
    let spec = ServiceSpec::naive(ArrivalPattern::Bursty, 120, 150, 20_000.0, 4.0, 300_000.0)
        .with_retry(120_000.0, 2, 10_000.0, 40_000.0, 0.25)
        .with_hedge(0.9)
        .with_shed(80_000.0);
    let cells = vec![
        tail_latency::cell(
            SchemeKind::Daemon,
            spec,
            Some(FaultPlan::new().module_crash(0, 2e5, 6e5)),
            SimConfig::test_scale(),
        ),
        tail_latency::cell(SchemeKind::Pq, spec, None, SimConfig::test_scale()),
        // Inert: no service — the historical trace-driven cluster path.
        CellSpec::cluster(
            &[("pr", SchemeKind::Daemon), ("sp", SchemeKind::Daemon)],
            2,
            SimConfig::test_scale(),
        ),
    ];
    let run = |jobs: usize| -> Vec<Vec<Metrics>> {
        run_cells_flat(&r, &TraceCache::new(), &cells, Shard::full(), jobs)
            .into_iter()
            .map(|s| s.expect("unsharded run fills every slot"))
            .collect()
    };
    let fmt = |slots: &[Vec<Metrics>]| -> Vec<String> {
        slots
            .iter()
            .map(|ms| {
                ms.iter().map(|m| m.to_json().to_string()).collect::<Vec<_>>().join("\n")
            })
            .collect()
    };
    let serial = run(1);
    // The service cells actually exercised the robustness machinery and
    // the inert cell never touched the ledger.
    let front = &serial[0][0];
    assert_eq!(
        front.requests_completed + front.requests_timed_out + front.requests_shed,
        spec.requests as u64,
        "service ledger does not cover every request"
    );
    assert_eq!(serial[2][0].requests_offered(), 0, "inert cell has no request ledger");
    assert_eq!(fmt(&serial), fmt(&run(4)), "service cells diverged across --jobs counts");
    assert_eq!(fmt(&serial), fmt(&run(1)), "service cells diverged across repeat runs");
}

/// Ring overflow is deterministic: a tiny ring must overflow, count its
/// drops identically on repeat runs, and retain an identical tail.
#[test]
fn ring_overflow_drops_are_deterministic() {
    let spec = ObsSpec::enabled().with_trace_capacity(16);
    let (_, ra) = run_once_obs(SchemeKind::Daemon, Some(spec));
    let (_, rb) = run_once_obs(SchemeKind::Daemon, Some(spec));
    let (ra, rb) = (ra.unwrap(), rb.unwrap());
    assert!(ra.trace.dropped() > 0, "a 16-event ring must overflow");
    assert_eq!(ra.trace.len(), 16, "ring holds exactly its capacity");
    assert_eq!(ra.trace.dropped(), rb.trace.dropped(), "drop counts diverged");
    let tail_a: Vec<Event> = ra.trace.events().cloned().collect();
    let tail_b: Vec<Event> = rb.trace.events().cloned().collect();
    assert_eq!(tail_a, tail_b, "retained tails diverged");
}
