//! Tests for the paper's §4.6/§4.7 extension features: the next-page
//! prefetcher (DaeMon "can flexibly support prefetchers" and throttle
//! their page requests via the selection scheme) and dirty-data
//! replication for memory-component failure handling.

use daemon_sim::config::{NetConfig, SimConfig};
use daemon_sim::schemes::SchemeKind;
use daemon_sim::system::run_workload;
use daemon_sim::workloads::{by_name, Scale};

fn cfg() -> SimConfig {
    SimConfig::test_scale().with_seed(5)
}

#[test]
fn prefetching_helps_streaming_workloads() {
    // hp streams vectors: sequential successor pages are exactly what the
    // next-page prefetcher covers.
    let w = by_name("hp").unwrap();
    let base = run_workload(&cfg(), SchemeKind::Daemon, w.as_ref(), Scale::Test);
    let pf = run_workload(
        &cfg().with_prefetch(2),
        SchemeKind::Daemon,
        w.as_ref(),
        Scale::Test,
    );
    assert!(
        pf.metrics.ipc() > base.metrics.ipc() * 0.98,
        "prefetch hurt streaming: {} vs {}",
        pf.metrics.ipc(),
        base.metrics.ipc()
    );
    assert!(
        pf.metrics.pages_moved > base.metrics.pages_moved,
        "prefetcher moved no extra pages"
    );
}

#[test]
fn prefetching_is_throttled_by_selection_not_harmful_on_random() {
    // pr's gathers are random: prefetched successors are mostly useless,
    // but the selection unit must keep the damage bounded.
    let w = by_name("pr").unwrap();
    let base = run_workload(&cfg(), SchemeKind::Daemon, w.as_ref(), Scale::Test);
    let pf = run_workload(
        &cfg().with_prefetch(4),
        SchemeKind::Daemon,
        w.as_ref(),
        Scale::Test,
    );
    assert!(
        pf.metrics.ipc() > base.metrics.ipc() * 0.7,
        "prefetch catastrophically hurt pr: {} vs {}",
        pf.metrics.ipc(),
        base.metrics.ipc()
    );
}

#[test]
fn prefetch_improves_local_coverage_on_sequential() {
    let w = by_name("sp").unwrap();
    let base = run_workload(&cfg(), SchemeKind::Daemon, w.as_ref(), Scale::Test);
    let pf = run_workload(
        &cfg().with_prefetch(2),
        SchemeKind::Daemon,
        w.as_ref(),
        Scale::Test,
    );
    assert!(
        pf.metrics.local_hit_ratio() >= base.metrics.local_hit_ratio() - 0.02,
        "prefetch reduced coverage: {} vs {}",
        pf.metrics.local_hit_ratio(),
        base.metrics.local_hit_ratio()
    );
}

#[test]
fn replication_multiplies_writeback_traffic() {
    let w = by_name("nw").unwrap(); // write-heavy
    let c2 = cfg()
        .with_memory_components(vec![NetConfig::new(100.0, 4.0); 2])
        .with_dirty_replicas(2);
    let c1 = cfg().with_memory_components(vec![NetConfig::new(100.0, 4.0); 2]);
    let base = run_workload(&c1, SchemeKind::Daemon, w.as_ref(), Scale::Test);
    let repl = run_workload(&c2, SchemeKind::Daemon, w.as_ref(), Scale::Test);
    assert!(
        repl.metrics.writeback_bytes > base.metrics.writeback_bytes,
        "replication produced no extra writeback traffic: {} vs {}",
        repl.metrics.writeback_bytes,
        base.metrics.writeback_bytes
    );
    // Replication is off the critical path: bounded slowdown.
    assert!(
        repl.metrics.ipc() > base.metrics.ipc() * 0.8,
        "replication on critical path: {} vs {}",
        repl.metrics.ipc(),
        base.metrics.ipc()
    );
}

#[test]
fn replication_caps_at_component_count() {
    let w = by_name("nw").unwrap();
    // Asking for 4 replicas with 2 components must not panic and must
    // behave like 2 replicas.
    let c = cfg()
        .with_memory_components(vec![NetConfig::new(100.0, 4.0); 2])
        .with_dirty_replicas(4);
    let m = run_workload(&c, SchemeKind::Daemon, w.as_ref(), Scale::Test);
    assert!(m.metrics.ipc() > 0.0);
}

#[test]
fn defaults_disable_both_extensions() {
    let c = SimConfig::default();
    assert_eq!(c.prefetch_pages, 0);
    assert_eq!(c.dirty_replicas, 1);
}
