//! Bench target regenerating the paper's table1 (see DESIGN.md index).
mod bench_common;

fn main() {
    bench_common::run_ids("table1_hw_cost", &["table1"]);
}
