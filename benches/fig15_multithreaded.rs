//! Bench target regenerating the paper's fig15 (see DESIGN.md index).
mod bench_common;

fn main() {
    bench_common::run_ids("fig15_multithreaded", &["fig15"]);
}
