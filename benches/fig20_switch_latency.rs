//! Bench target regenerating the paper's fig20 (see DESIGN.md index).
mod bench_common;

fn main() {
    bench_common::run_ids("fig20_switch_latency", &["fig20"]);
}
