//! Shared bench plumbing (no criterion offline): each bench binary runs a
//! set of paper experiments at the configured effort, printing the same
//! rows/series the paper's figures plot, plus wall-time per run.
//!
//! Effort: `DAEMON_BENCH_FULL=1` runs the full 2M-access paper traces;
//! the default uses 600K-access truncations so a complete `cargo bench`
//! finishes in minutes while preserving every trend.
//!
//! All ids are batched into one flat cell list through the experiment
//! orchestrator, so traces are generated once per key and every cell of
//! every requested figure fans out across the worker pool together.

// Benches measure wall-clock throughput and stamp artifacts with host
// time — the one place outside the CLI where reading the clock is the
// point, not entropy.
#![allow(clippy::disallowed_methods)]

use daemon_sim::experiments::orchestrator::{self, Shard, SweepResult};
use daemon_sim::experiments::Runner;
use daemon_sim::util::json::Json;
use daemon_sim::workloads::cache::TraceCache;
use daemon_sim::workloads::Scale;

/// Build metadata stamped into every bench JSON artifact, so recorded
/// numbers stay interpretable once the perf trajectory accumulates.
#[allow(dead_code)] // only JSON-emitting bench binaries use this
pub fn build_metadata() -> Json {
    Json::obj(vec![
        ("crate_version", Json::str(env!("CARGO_PKG_VERSION"))),
        (
            "profile",
            Json::str(if cfg!(debug_assertions) { "debug" } else { "release" }),
        ),
        ("os", Json::str(std::env::consts::OS)),
        ("arch", Json::str(std::env::consts::ARCH)),
        (
            "unix_time",
            Json::num(
                std::time::SystemTime::now()
                    .duration_since(std::time::UNIX_EPOCH)
                    .map(|d| d.as_secs() as f64)
                    .unwrap_or(0.0),
            ),
        ),
    ])
}

/// Write a machine-readable bench artifact to `BENCH_<name>.json` in the
/// working directory (override the directory with `DAEMON_BENCH_DIR`) —
/// the recorded perf-trajectory counterpart of the human-readable table.
#[allow(dead_code)] // only JSON-emitting bench binaries use this
pub fn write_bench_json(name: &str, payload: Json) {
    let dir = std::env::var("DAEMON_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
    let path = std::path::Path::new(&dir).join(format!("BENCH_{name}.json"));
    match std::fs::write(&path, format!("{payload}\n")) {
        Ok(()) => println!("[wrote {}]", path.display()),
        Err(e) => eprintln!("[bench json: failed to write {}: {e}]", path.display()),
    }
}

#[allow(dead_code)] // not every bench binary uses both helpers
pub fn bench_runner() -> Runner {
    if std::env::var("DAEMON_BENCH_FULL").is_ok() {
        Runner::paper()
    } else {
        Runner {
            scale: Scale::Paper,
            max_accesses: 600_000,
            threads: daemon_sim::experiments::common::default_threads(),
        }
    }
}

#[allow(dead_code)] // perf_hot_path uses only bench_runner
pub fn run_ids(title: &str, ids: &[&str]) {
    // `cargo bench` passes --bench; ignore unknown args.
    println!("==== bench: {title} ====");
    let r = bench_runner();
    let ids: Vec<String> = ids.iter().map(|s| s.to_string()).collect();
    let t0 = std::time::Instant::now();
    let cache = TraceCache::global();
    match orchestrator::sweep(&ids, &r, cache, Shard::full(), r.threads) {
        Ok(SweepResult::Tables(sets)) => {
            for (id, tables) in sets {
                for t in tables {
                    println!("{}", t.render());
                }
                println!("[{id}]");
            }
            let stats = cache.stats();
            println!(
                "[total: {:.1}s; traces {} generated / {} reused]\n",
                t0.elapsed().as_secs_f64(),
                stats.misses,
                stats.hits
            );
        }
        Ok(SweepResult::Shard(_)) => unreachable!("bench runs are never sharded"),
        Err(e) => println!("bench error: {e}"),
    }
}
