//! Shared bench plumbing (no criterion offline): each bench binary runs a
//! set of paper experiments at the configured effort, printing the same
//! rows/series the paper's figures plot, plus wall-time per experiment.
//!
//! Effort: `DAEMON_BENCH_FULL=1` runs the full 2M-access paper traces;
//! the default uses 600K-access truncations so a complete `cargo bench`
//! finishes in minutes while preserving every trend.

use daemon_sim::experiments::{run_experiment, Runner};
use daemon_sim::workloads::Scale;

pub fn bench_runner() -> Runner {
    if std::env::var("DAEMON_BENCH_FULL").is_ok() {
        Runner::paper()
    } else {
        Runner {
            scale: Scale::Paper,
            max_accesses: 600_000,
            threads: daemon_sim::experiments::common::default_threads(),
        }
    }
}

pub fn run_ids(title: &str, ids: &[&str]) {
    // `cargo bench` passes --bench; ignore unknown args.
    println!("==== bench: {title} ====");
    let r = bench_runner();
    for id in ids {
        let t0 = std::time::Instant::now();
        match run_experiment(id, &r) {
            Some(tables) => {
                for t in tables {
                    println!("{}", t.render());
                }
                println!("[{id}: {:.1}s]\n", t0.elapsed().as_secs_f64());
            }
            None => println!("unknown experiment id {id}"),
        }
    }
}
