//! §Perf microbenchmark: raw simulator throughput (accesses/second) per
//! scheme on a fixed pr trace — the number the performance pass optimizes.
mod bench_common;

use daemon_sim::config::SimConfig;
use daemon_sim::schemes::SchemeKind;
use daemon_sim::system::Machine;
use daemon_sim::workloads::{by_name, Scale};

fn main() {
    let w = by_name("pr").unwrap();
    let cfg = SimConfig::default().with_seed(1);
    let trace = w.generate(cfg.seed, Scale::Paper).truncated(2_000_000);
    println!("==== bench: perf_hot_path ({} accesses) ====", trace.accesses.len());
    for kind in [
        SchemeKind::Local,
        SchemeKind::Remote,
        SchemeKind::CacheLine,
        SchemeKind::Lc,
        SchemeKind::Pq,
        SchemeKind::Daemon,
    ] {
        // Warmup + 3 measured iterations.
        let mut rates = Vec::new();
        for i in 0..4 {
            let mut m = Machine::new(
                cfg.clone(),
                kind,
                trace.footprint_pages,
                vec![w.profile()],
                None,
            );
            let t0 = std::time::Instant::now();
            m.run(std::slice::from_ref(&trace));
            let dt = t0.elapsed().as_secs_f64();
            if i > 0 {
                rates.push(trace.accesses.len() as f64 / dt / 1e6);
            }
        }
        let mean = rates.iter().sum::<f64>() / rates.len() as f64;
        let min = rates.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = rates.iter().cloned().fold(0.0f64, f64::max);
        println!(
            "{:<18} {:6.2} M acc/s  (min {:.2}, max {:.2})",
            kind.name(),
            mean,
            min,
            max
        );
    }
}
