//! §Perf microbenchmark: raw simulator throughput (accesses/second) per
//! scheme on a fixed pr trace — the number the performance pass optimizes.
//!
//! Besides the human-readable table, the run records
//! `BENCH_perf_hot_path.json` (scheme → M accesses/s + build metadata +
//! the `pq`+`daemon` aggregate the acceptance gate compares across
//! binaries), so the perf trajectory is tracked instead of lost in CI
//! logs.  Knobs: `DAEMON_BENCH_ACCESSES` truncates the trace (CI smoke
//! uses a small cap; default 2M), `DAEMON_BENCH_DIR` redirects the JSON.
mod bench_common;

use daemon_sim::config::SimConfig;
use daemon_sim::schemes::SchemeKind;
use daemon_sim::system::Machine;
use daemon_sim::util::json::Json;
use daemon_sim::workloads::{by_name, Scale};

fn main() {
    let accesses: usize = std::env::var("DAEMON_BENCH_ACCESSES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2_000_000);
    let w = by_name("pr").unwrap();
    let cfg = SimConfig::default().with_seed(1);
    let trace = w.generate(cfg.seed, Scale::Paper).truncated(accesses);
    println!("==== bench: perf_hot_path ({} accesses) ====", trace.accesses.len());
    let mut schemes = Vec::new();
    let mut agg_gate = 0.0f64;
    for kind in [
        SchemeKind::Local,
        SchemeKind::Remote,
        SchemeKind::CacheLine,
        SchemeKind::Lc,
        SchemeKind::Pq,
        SchemeKind::Daemon,
    ] {
        // Warmup + 3 measured iterations.
        let mut rates = Vec::new();
        for i in 0..4 {
            let mut m = Machine::new(
                cfg.clone(),
                kind,
                trace.footprint_pages,
                vec![w.profile()],
                None,
            );
            // Wall-clock throughput is the measured quantity here.
            #[allow(clippy::disallowed_methods)]
            let t0 = std::time::Instant::now();
            m.run(std::slice::from_ref(&trace));
            let dt = t0.elapsed().as_secs_f64();
            if i > 0 {
                rates.push(trace.accesses.len() as f64 / dt / 1e6);
            }
        }
        let mean = rates.iter().sum::<f64>() / rates.len() as f64;
        let min = rates.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = rates.iter().cloned().fold(0.0f64, f64::max);
        println!(
            "{:<18} {:6.2} M acc/s  (min {:.2}, max {:.2})",
            kind.name(),
            mean,
            min,
            max
        );
        if matches!(kind, SchemeKind::Pq | SchemeKind::Daemon) {
            agg_gate += mean;
        }
        schemes.push((
            kind.name().to_string(),
            Json::obj(vec![
                ("mean_macc_per_s", Json::num(mean)),
                ("min_macc_per_s", Json::num(min)),
                ("max_macc_per_s", Json::num(max)),
            ]),
        ));
    }
    println!("pq+daemon aggregate {agg_gate:.2} M acc/s (the >=1.5x gate quantity)");
    // >=1.5x acceptance gate vs the committed baseline snapshot.  The
    // comparison is only binding when the baseline's numbers are
    // CI-measured (`source: "measured"`); an estimate-seeded snapshot
    // keeps the gate informational until a real artifact replaces it.
    let baseline_path = std::env::var("DAEMON_BENCH_BASELINE")
        .unwrap_or_else(|_| "BENCH_01.json".to_string());
    match std::fs::read_to_string(&baseline_path).ok().and_then(|s| Json::parse(&s).ok()) {
        Some(base) => {
            let b = base
                .get("pq_daemon_aggregate_macc_per_s")
                .and_then(Json::as_f64)
                .unwrap_or(0.0);
            let measured =
                base.get("source").and_then(Json::as_str) == Some("measured");
            if b > 0.0 {
                let ratio = agg_gate / b;
                let verdict = if ratio >= 1.5 {
                    "PASS"
                } else if measured {
                    "FAIL"
                } else {
                    "n/a (informational)"
                };
                println!(
                    "vs baseline {baseline_path}: {ratio:.2}x of {b:.2} M acc/s \
                     ({} baseline) — >=1.5x gate: {verdict}",
                    if measured { "measured" } else { "estimate" }
                );
            } else {
                println!("baseline {baseline_path} carries no aggregate; gate skipped");
            }
        }
        None => println!("no baseline snapshot at {baseline_path}; gate skipped"),
    }
    bench_common::write_bench_json(
        "perf_hot_path",
        Json::obj(vec![
            ("bench", Json::str("perf_hot_path")),
            ("workload", Json::str("pr")),
            ("accesses", Json::num(trace.accesses.len() as f64)),
            ("iterations", Json::num(3.0)),
            (
                "schemes",
                Json::Obj(schemes.into_iter().collect()),
            ),
            ("pq_daemon_aggregate_macc_per_s", Json::num(agg_gate)),
            // This run's numbers are real wall-clock measurements; the
            // committed BENCH_01.json seed is marked "estimate" until a
            // CI artifact (which carries this field) replaces it.
            ("source", Json::str("measured")),
            ("build", bench_common::build_metadata()),
        ]),
    );
}
