//! Bench target regenerating the paper's fig21 (see DESIGN.md index).
mod bench_common;

fn main() {
    bench_common::run_ids("fig21_bandwidth_factor", &["fig21"]);
}
