//! Bench target regenerating the paper's fig12 (see DESIGN.md index).
mod bench_common;

fn main() {
    bench_common::run_ids("fig12_compression", &["fig12"]);
}
