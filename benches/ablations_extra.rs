//! Bench target regenerating the paper's ablation_dirty_threshold and
//! ablation_buffer_size experiments (see DESIGN.md index).
mod bench_common;

fn main() {
    bench_common::run_ids("ablations_extra", &["ablation_dirty_threshold","ablation_buffer_size"]);
}
