//! Bench target regenerating the paper's fig17 (see DESIGN.md index).
mod bench_common;

fn main() {
    bench_common::run_ids("fig17_multi_memory", &["fig17"]);
}
