//! Bench target regenerating the paper's fig19 (see DESIGN.md index).
mod bench_common;

fn main() {
    bench_common::run_ids("fig19_bandwidth_util", &["fig19"]);
}
