//! Bench target regenerating the paper's fig13 (see DESIGN.md index).
mod bench_common;

fn main() {
    bench_common::run_ids("fig13_disturbance", &["fig13"]);
}
