//! Bench target regenerating the paper's fig11 (see DESIGN.md index).
mod bench_common;

fn main() {
    bench_common::run_ids("fig11_partition_ratio", &["fig11"]);
}
