//! Bench target regenerating the paper's fig22 (see DESIGN.md index).
mod bench_common;

fn main() {
    bench_common::run_ids("fig22_memory_scaling", &["fig22"]);
}
