//! Bench target regenerating the paper's headline (see DESIGN.md index).
mod bench_common;

fn main() {
    bench_common::run_ids("headline", &["headline"]);
}
