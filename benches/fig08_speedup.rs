//! Bench target regenerating the paper's fig8 (see DESIGN.md index).
mod bench_common;

fn main() {
    bench_common::run_ids("fig08_speedup", &["fig8"]);
}
