//! Bench target regenerating the paper's fig16 (see DESIGN.md index).
mod bench_common;

fn main() {
    bench_common::run_ids("fig16_fifo", &["fig16"]);
}
