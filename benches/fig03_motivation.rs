//! Bench target regenerating the paper's fig3 (see DESIGN.md index).
mod bench_common;

fn main() {
    bench_common::run_ids("fig03_motivation", &["fig3"]);
}
