//! Bench target regenerating the paper's fig18 (see DESIGN.md index).
mod bench_common;

fn main() {
    bench_common::run_ids("fig18_multi_workload", &["fig18"]);
}
