//! Bench target regenerating the paper's fig10 (see DESIGN.md index).
mod bench_common;

fn main() {
    bench_common::run_ids("fig10_hit_ratio", &["fig10"]);
}
