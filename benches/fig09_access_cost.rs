//! Bench target regenerating the paper's fig9 (see DESIGN.md index).
mod bench_common;

fn main() {
    bench_common::run_ids("fig09_access_cost", &["fig9"]);
}
