"""L2 JAX model: batched compression + transfer cost model.

This is the compute graph the rust coordinator executes through PJRT on its
(build-time compiled, run-time loaded) artifact.  Given a batch of pages and
the current network operating point it returns, per page:

  est_bytes[B, 3]   — estimated compressed bytes under [lz, fpcbdi, fve]
                      (from the L1 pallas kernel)
  page_cycles[B]    — estimated cycles to migrate the page compressed with
                      DaeMon's LZ scheme through the page-partition share of
                      the link, including switch latency and (de)compression
  line_cycles[B]    — estimated cycles for one 64B critical cache-line
                      through the line-partition share
  advantage[B]      — log-ratio line/page cost: >0 means the cache line is
                      expected to arrive first (favor line movement)

Network parameters arrive as a single f32[6] vector so the artifact stays
shape-generic across operating points:

  params = [ link_bytes_per_cycle,   # network bandwidth at core clock
             switch_cycles,          # propagation+switching delay
             partition_ratio,        # fraction reserved for cache lines
             line_bytes,             # 64
             decomp_cycles,          # 64 (MXT) per 1KB chunk x 4 chunks
             mem_bytes_per_cycle ]   # DRAM bus bandwidth at core clock

The whole function (pallas kernel included) lowers into ONE HLO module via
``aot.py``; python never runs at simulation time.
"""

import jax.numpy as jnp

from .kernels.compress_model import (
    PAGE_BYTES,
    compress_sizes,
)

# Fixed artifact batch size: the rust runtime pads partial batches.
AOT_BATCH = 64


def cost_model(pages, params):
    """Forward model.  ``pages: i32[B, 1024]``, ``params: f32[6]``.

    Returns ``(est_bytes[B,3], page_cycles[B], line_cycles[B], advantage[B])``.
    """
    est = compress_sizes(pages)  # [B, 3] via the L1 pallas kernel

    link_bpc = params[0]
    switch_cyc = params[1]
    ratio = params[2]
    line_bytes = params[3]
    decomp_cyc = params[4]
    mem_bpc = params[5]

    # Bandwidth partitioning (§4.1): pages see (1-ratio) of the link, lines
    # see ratio.  Both also cross the remote memory bus (full width).
    page_share = jnp.maximum(link_bpc * (1.0 - ratio), 1e-6)
    line_share = jnp.maximum(link_bpc * ratio, 1e-6)

    lz_bytes = est[:, 0]
    page_cycles = (
        switch_cyc
        + lz_bytes / page_share  # serialized over the page partition
        + jnp.float32(PAGE_BYTES) / mem_bpc  # remote DRAM read (uncompressed)
        + decomp_cyc  # MXT decompression at the compute side
    )
    line_cycles = jnp.full_like(
        page_cycles, switch_cyc + line_bytes / line_share + line_bytes / mem_bpc
    )

    advantage = jnp.log(page_cycles) - jnp.log(line_cycles)
    return est, page_cycles, line_cycles, advantage


def example_args():
    """Static example arguments used for AOT lowering."""
    import jax

    pages = jax.ShapeDtypeStruct((AOT_BATCH, 1024), jnp.int32)
    params = jax.ShapeDtypeStruct((6,), jnp.float32)
    return pages, params
