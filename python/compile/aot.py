"""AOT lowering: L2 cost model (with embedded L1 pallas kernel) -> HLO text.

HLO *text* is the interchange format, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).  The text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts/compress_model.hlo.txt
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import cost_model, example_args


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_cost_model() -> str:
    lowered = jax.jit(cost_model).lower(*example_args())
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/compress_model.hlo.txt")
    args = ap.parse_args()
    text = lower_cost_model()
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(text)
    print(f"wrote {len(text)} chars to {args.out}")


if __name__ == "__main__":
    main()
