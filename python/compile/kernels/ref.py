"""Pure-jnp oracle for the L1 compression-model kernel.

No pallas: straight jnp over the full batch.  pytest asserts the pallas
kernel (interpret mode) matches this to float tolerance across shapes and
content distributions (hypothesis sweeps).
"""

import jax.numpy as jnp

from .compress_model import (
    BLOCKS_PER_PAGE,
    WORDS_PER_BLOCK,
    WORDS_PER_PAGE,
    _block_features,
    _estimate_sizes,
)


def compress_sizes_ref(pages):
    """Reference implementation of ``compress_model.compress_sizes``.

    Accepts any ``i32[B, 1024]`` (no PAGE_TILE divisibility requirement).
    """
    b, w = pages.shape
    assert w == WORDS_PER_PAGE, pages.shape
    words = pages.reshape(b, BLOCKS_PER_PAGE, WORDS_PER_BLOCK)
    feats = _block_features(words)
    return _estimate_sizes(*feats)
