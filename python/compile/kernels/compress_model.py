"""L1 Pallas kernel: hardware link-compression unit model.

DaeMon (§4.4) adds IBM-MXT-style compression units to every compute and
memory component: 4 engines, each operating on a 256B sub-block of a 1KB
chunk with a 256B shared dictionary, 64-cycle latency.  The *timing* lives in
the rust simulator (L3); this kernel models the *data-dependent outcome* —
the compressed size a page would reach under each of the paper's three
algorithm families (Fig. 12):

  - ``lz``     : ratio-optimized LZ77 / MXT        (DaeMon's default)
  - ``fpcbdi`` : latency-optimized FPC + BDI hybrid
  - ``fve``    : latency-optimized frequent-value encoding

A 4KB page is viewed as 1024 little-endian i32 words = 16 blocks x 64 words
(one block = 256B = one MXT engine granule).  Per block we extract the
features each algorithm family exploits, then fold them into a byte estimate
with fixed per-family coefficients.  The rust side implements the *same*
formula natively (``compress/est.rs``) so the PJRT path is bit-comparable,
and separately implements the real algorithms as ground truth.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's 4x256B
engine structure becomes the kernel's tile shape — pages are gridded over the
batch dimension with BlockSpec, each grid step holding a (PAGE_TILE, 1024)
i32 tile in VMEM; the dictionary CAM becomes a vectorized broadcast compare
(VPU integer ops; the MXU is not applicable and is deliberately not forced).

All shapes are static; the kernel is lowered with ``interpret=True`` because
the CPU PJRT plugin cannot execute Mosaic custom-calls.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Page geometry: 4KB = 1024 i32 words = WORDS_PER_BLOCK x BLOCKS_PER_PAGE.
WORDS_PER_PAGE = 1024
BLOCKS_PER_PAGE = 16
WORDS_PER_BLOCK = 64
PAGE_BYTES = 4096
BLOCK_BYTES = 256
# Dictionary window for the FVE CAM proxy (first DICT_WORDS distinct-ish
# words of each block act as the 256B shared dictionary of the MXT engine).
DICT_WORDS = 8
# Number of algorithm families estimated (lz, fpcbdi, fve).
N_ALGOS = 3
# Batch tile: pages per grid step.  (PAGE_TILE, 1024) i32 = 32KB in VMEM.
PAGE_TILE = 8

# Per-family linear coefficients folding block features into byte estimates.
# Calibrated against the native rust implementations on the synthetic page
# generator (see rust/tests/pjrt_estimator.rs); mirrored EXACTLY in
# rust/src/compress/est.rs — keep the two in sync.
LZ_RUN_GAIN = 3.5        # bytes saved per repeated word (run/match)
LZ_DICT_GAIN = 2.5       # bytes saved per dictionary-window hit
LZ_ZERO_GAIN = 3.8       # bytes saved per zero word
FPC_ZERO_GAIN = 3.5      # FPC zero-word pattern: 4B -> ~3 bits + prefix
FPC_NARROW_GAIN = 2.75   # FPC sign-extended narrow word
BDI_DELTA_GAIN = 2.0     # BDI 4B->2B delta encoding
FVE_HIT_GAIN = 3.0       # FVE dictionary hit: 4B -> ~1B index
HEADER_BYTES = 8.0       # per-block metadata for any scheme
CALIB_POW = 0.55         # saturating fit to the real LZ77 encoder


def _block_features(words):
    """Per-256B-block features over ``words[..., 16, 64] : i32``.

    Returns a tuple of f32 arrays shaped ``[..., 16]``:
      zeros   — words equal to 0                      (FPC/LZ)
      narrow  — words representable in 8 bits         (FPC)
      runs    — words equal to their predecessor      (LZ run-length proxy)
      deltas  — words within 2^15 of the block base   (BDI)
      dhits   — words matching the first-8-word dict  (FVE/LZ CAM proxy)
    """
    zeros = jnp.sum((words == 0), axis=-1).astype(jnp.float32)
    narrow = jnp.sum((jnp.abs(words) < 128) & (words != 0), axis=-1).astype(
        jnp.float32
    )
    runs = jnp.sum(words[..., 1:] == words[..., :-1], axis=-1).astype(jnp.float32)
    base = words[..., 0:1]
    deltas = jnp.sum(
        (jnp.abs(words - base) < 32768) & (words != 0), axis=-1
    ).astype(jnp.float32)
    # Dictionary CAM: match each word against the block's first DICT_WORDS
    # words, excluding trivial self-match of position j<DICT_WORDS against
    # itself by only counting positions >= DICT_WORDS.
    dict_win = words[..., :DICT_WORDS]
    tail = words[..., DICT_WORDS:]
    hit = jnp.any(tail[..., :, None] == dict_win[..., None, :], axis=-1)
    dhits = jnp.sum(hit, axis=-1).astype(jnp.float32)
    return zeros, narrow, runs, deltas, dhits


def _estimate_sizes(zeros, narrow, runs, deltas, dhits):
    """Fold block features into per-page byte estimates ``[..., 3] : f32``.

    Order: ``[lz, fpcbdi, fve]``.  Estimates are clamped to
    ``[BLOCKS_PER_PAGE * HEADER_BYTES, PAGE_BYTES]`` — compression never
    produces more than the raw page (the hardware falls back to raw).
    """
    raw = jnp.float32(BLOCK_BYTES)
    lz = raw + HEADER_BYTES - LZ_ZERO_GAIN * zeros - LZ_RUN_GAIN * runs
    lz = lz - LZ_DICT_GAIN * dhits
    fpcbdi = (
        raw
        + HEADER_BYTES
        - FPC_ZERO_GAIN * zeros
        - FPC_NARROW_GAIN * narrow
        - BDI_DELTA_GAIN * jnp.maximum(deltas - narrow, 0.0) * 0.5
    )
    fve = raw + HEADER_BYTES - FVE_HIT_GAIN * dhits - FPC_ZERO_GAIN * zeros * 0.5
    per_block = jnp.stack([lz, fpcbdi, fve], axis=-1)
    # Saturating calibration against the real LZ77 implementation: linear
    # feature gains over-credit structured blocks (real encoders pay
    # per-token overheads), so the compressed fraction is raised to
    # CALIB_POW — fit so profile means track rust compress::lz within ~25%
    # (see rust/tests/pjrt_estimator.rs and examples/est_probe.rs).
    frac = jnp.clip((per_block - HEADER_BYTES) / raw, 0.0, 1.0)
    per_block = HEADER_BYTES + raw * jnp.power(frac, CALIB_POW)
    return jnp.sum(per_block, axis=-2)  # sum over the 16 blocks


def _compress_kernel(pages_ref, sizes_ref):
    """Pallas kernel body: ``pages_ref[(PAGE_TILE, 1024) i32]`` ->
    ``sizes_ref[(PAGE_TILE, 3) f32]``."""
    words = pages_ref[...].reshape(PAGE_TILE, BLOCKS_PER_PAGE, WORDS_PER_BLOCK)
    feats = _block_features(words)
    sizes_ref[...] = _estimate_sizes(*feats)


def compress_sizes(pages):
    """Estimated compressed bytes per page per algorithm family.

    Args:
      pages: ``i32[B, 1024]`` with ``B % PAGE_TILE == 0`` — a batch of 4KB
        pages as little-endian words.
    Returns:
      ``f32[B, 3]`` — estimated bytes under ``[lz, fpcbdi, fve]``.
    """
    b, w = pages.shape
    if w != WORDS_PER_PAGE:
        raise ValueError(f"pages must be [B, {WORDS_PER_PAGE}], got {pages.shape}")
    if b % PAGE_TILE != 0:
        raise ValueError(f"batch {b} must be a multiple of PAGE_TILE={PAGE_TILE}")
    grid = (b // PAGE_TILE,)
    return pl.pallas_call(
        _compress_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((PAGE_TILE, WORDS_PER_PAGE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((PAGE_TILE, N_ALGOS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, N_ALGOS), jnp.float32),
        interpret=True,
    )(pages)


@partial(jax.jit, static_argnames=())
def compress_sizes_jit(pages):
    return compress_sizes(pages)
