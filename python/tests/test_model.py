"""L2 cost-model shape/semantics tests + AOT lowering smoke test."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.model import AOT_BATCH, cost_model, example_args
from compile.kernels.compress_model import PAGE_BYTES, WORDS_PER_PAGE


def _params(link_bpc=4.7, switch_cyc=360.0, ratio=0.25, line_bytes=64.0,
            decomp_cyc=256.0, mem_bpc=4.7):
    return jnp.asarray(
        [link_bpc, switch_cyc, ratio, line_bytes, decomp_cyc, mem_bpc],
        dtype=jnp.float32,
    )


def _pages(seed=0, b=AOT_BATCH, kind="mixed"):
    rng = np.random.default_rng(seed)
    if kind == "zeros":
        arr = np.zeros((b, WORDS_PER_PAGE), dtype=np.int32)
    else:
        vals = rng.integers(-5, 5, size=(b, WORDS_PER_PAGE // 8)).astype(np.int32)
        runs = np.repeat(vals, 8, axis=1)
        rand = rng.integers(-(2**31), 2**31 - 1, size=(b, WORDS_PER_PAGE)).astype(
            np.int64
        ).astype(np.int32)
        mask = rng.random((b, WORDS_PER_PAGE)) < 0.6
        arr = np.where(mask, runs, rand).astype(np.int32)
    return jnp.asarray(arr)


def test_shapes():
    est, pc, lc, adv = cost_model(_pages(), _params())
    assert est.shape == (AOT_BATCH, 3)
    assert pc.shape == (AOT_BATCH,)
    assert lc.shape == (AOT_BATCH,)
    assert adv.shape == (AOT_BATCH,)


def test_line_always_cheaper_than_page_on_fair_link():
    """A 64B line through 25% of the link beats a 4KB page through 75%."""
    _, pc, lc, _ = cost_model(_pages(kind="rand"), _params())
    assert (lc < pc).all()


def test_compression_shrinks_page_cost():
    _, pc_zero, _, _ = cost_model(_pages(kind="zeros"), _params())
    _, pc_rand, _, _ = cost_model(_pages(seed=3), _params())
    assert pc_zero.mean() < pc_rand.mean()


def test_advantage_sign_matches_costs():
    _, pc, lc, adv = cost_model(_pages(), _params())
    np.testing.assert_allclose(
        np.asarray(adv), np.log(np.asarray(pc)) - np.log(np.asarray(lc)),
        rtol=1e-5,
    )


def test_higher_ratio_speeds_lines_slows_pages():
    _, pc25, lc25, _ = cost_model(_pages(), _params(ratio=0.25))
    _, pc80, lc80, _ = cost_model(_pages(), _params(ratio=0.80))
    assert lc80.mean() < lc25.mean()
    assert pc80.mean() > pc25.mean()


def test_aot_lowering_produces_hlo_text():
    from compile.aot import lower_cost_model

    text = lower_cost_model()
    assert "HloModule" in text
    assert len(text) > 1000


def test_aot_example_args_match_model():
    pages_spec, params_spec = example_args()
    assert pages_spec.shape == (AOT_BATCH, 1024)
    assert params_spec.shape == (6,)
    # jit(lower) must accept the specs without tracing errors.
    jax.jit(cost_model).lower(pages_spec, params_spec)
