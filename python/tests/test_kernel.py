"""Kernel-vs-ref correctness: the CORE L1 signal.

The pallas kernel (interpret mode) must match the pure-jnp oracle bit-for-
bit (both are f32 computations over identical ops, so we allow only tiny
tolerance).  Hypothesis sweeps batch sizes and content distributions.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.compress_model import (
    HEADER_BYTES,
    BLOCKS_PER_PAGE,
    BLOCK_BYTES,
    N_ALGOS,
    PAGE_BYTES,
    PAGE_TILE,
    WORDS_PER_PAGE,
    compress_sizes,
)
from compile.kernels.ref import compress_sizes_ref

MAX_EST = BLOCKS_PER_PAGE * (BLOCK_BYTES + HEADER_BYTES)
MIN_EST = BLOCKS_PER_PAGE * HEADER_BYTES


def _random_pages(rng, b, kind):
    """Synthetic page contents with controlled compressibility."""
    if kind == "zeros":
        return np.zeros((b, WORDS_PER_PAGE), dtype=np.int32)
    if kind == "runs":
        vals = rng.integers(-5, 5, size=(b, WORDS_PER_PAGE // 8)).astype(np.int32)
        return np.repeat(vals, 8, axis=1)
    if kind == "narrow":
        return rng.integers(-127, 128, size=(b, WORDS_PER_PAGE)).astype(np.int32)
    if kind == "random":
        return rng.integers(
            np.iinfo(np.int32).min,
            np.iinfo(np.int32).max,
            size=(b, WORDS_PER_PAGE),
            dtype=np.int64,
        ).astype(np.int32)
    if kind == "mixed":
        a = _random_pages(rng, b, "runs")
        z = _random_pages(rng, b, "random")
        mask = rng.random((b, WORDS_PER_PAGE)) < 0.5
        return np.where(mask, a, z).astype(np.int32)
    raise ValueError(kind)


KINDS = ["zeros", "runs", "narrow", "random", "mixed"]


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("b", [PAGE_TILE, 4 * PAGE_TILE])
def test_kernel_matches_ref(kind, b):
    rng = np.random.default_rng(hash((kind, b)) % 2**32)
    pages = jnp.asarray(_random_pages(rng, b, kind))
    got = np.asarray(compress_sizes(pages))
    want = np.asarray(compress_sizes_ref(pages))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(
    b_tiles=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    kind=st.sampled_from(KINDS),
)
def test_kernel_matches_ref_hypothesis(b_tiles, seed, kind):
    rng = np.random.default_rng(seed)
    pages = jnp.asarray(_random_pages(rng, b_tiles * PAGE_TILE, kind))
    got = np.asarray(compress_sizes(pages))
    want = np.asarray(compress_sizes_ref(pages))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-3)


def test_output_shape_and_bounds():
    rng = np.random.default_rng(0)
    pages = jnp.asarray(_random_pages(rng, 2 * PAGE_TILE, "mixed"))
    out = np.asarray(compress_sizes(pages))
    assert out.shape == (2 * PAGE_TILE, N_ALGOS)
    assert (out >= MIN_EST - 1e-3).all()
    assert (out <= MAX_EST + 1e-3).all()


def test_zero_pages_maximally_compressible():
    pages = jnp.zeros((PAGE_TILE, WORDS_PER_PAGE), dtype=jnp.int32)
    out = np.asarray(compress_sizes(pages))
    # All-zero pages: LZ collapses to the metadata floor; FPC's floor is a
    # ~3-bit prefix per word plus the saturating-calibration overhead (the
    # calibration is fit to LZ — see CALIB_POW), so allow 0.4 pages.
    assert (out[:, 0] <= MIN_EST + 64).all(), out[0]
    assert (out[:, 1] <= 0.40 * PAGE_BYTES).all(), out[0]


def test_random_pages_incompressible():
    rng = np.random.default_rng(7)
    pages = jnp.asarray(_random_pages(rng, PAGE_TILE, "random"))
    out = np.asarray(compress_sizes(pages))
    # Pure-random i32 pages should estimate near raw size (ratio < 1.25x).
    assert (out > 0.8 * PAGE_BYTES).all(), out.min()


def test_compressibility_ordering():
    """More structure => smaller estimate, for every algorithm family."""
    rng = np.random.default_rng(21)
    zeros = np.asarray(compress_sizes(jnp.asarray(_random_pages(rng, PAGE_TILE, "zeros"))))
    runs = np.asarray(compress_sizes(jnp.asarray(_random_pages(rng, PAGE_TILE, "runs"))))
    rand = np.asarray(compress_sizes(jnp.asarray(_random_pages(rng, PAGE_TILE, "random"))))
    assert zeros.mean(axis=0)[0] < runs.mean(axis=0)[0] < rand.mean(axis=0)[0]
    assert zeros.mean(axis=0)[1] < rand.mean(axis=0)[1]
    assert zeros.mean(axis=0)[2] < rand.mean(axis=0)[2]


def test_rejects_bad_shapes():
    with pytest.raises(ValueError):
        compress_sizes(jnp.zeros((PAGE_TILE, 512), dtype=jnp.int32))
    with pytest.raises(ValueError):
        compress_sizes(jnp.zeros((PAGE_TILE + 1, WORDS_PER_PAGE), dtype=jnp.int32))
